//! Per-kernel workload models.
//!
//! Each model maps (matrix, n) → [`WorkEstimate`] from the kernel's actual
//! access pattern and decomposition, using the real matrix structure
//! (row-length distribution, empty rows, slice padding).
//!
//! ### Calibrated achieved-bandwidth constants
//!
//! | kernel | `mem_efficiency` | rationale |
//! |---|---|---|
//! | row-split (ours)  | 0.85 | fully coalesced row-major streaming of A, B, C |
//! | merge-based (ours)| 0.85 | same access pattern + flat nonzero stream |
//! | csrmm             | 0.25 | column-major B: 32-lane strided gathers waste most of each transaction |
//! | csrmm2            | 0.62 | row-major B coalesced, but column-major C + smem staging |
//! | SELL-P            | 0.70 | slice-local gathers via texture path |
//! | sgemm             | 0.90 | dense streaming, near-ideal |
//!
//! These stand in for microbenchmarks we cannot run on real hardware; all
//! *shape* (who degrades where) comes from the structural terms.

use crate::formats::{Csr, SellP};
use crate::loadbalance::{Partitioner, RowSplit};

use super::gpu::{simulate, GpuSpec, KernelReport, WorkEstimate};

/// B-row L2 reuse factor: when many nonzeros share B rows (dense-ish
/// matrices), gathered rows hit L2.  `nnz/k` is the mean reuse per B row;
/// the cap reflects K40c L2 capacity (calibrated against the Fig. 7
/// crossover).
fn b_reuse(a: &Csr) -> f64 {
    if a.k == 0 {
        return 1.0;
    }
    ((a.nnz() as f64 / a.k as f64) / 32.0).clamp(1.0, 2.0)
}

/// Issue cost of one gathered B element, in FMA-lane-instruction
/// equivalents.  Kepler has 32 LD/ST units per SM against 192 FMA lanes
/// (6×), plus address setup — gather-heavy kernels are issue-bound on
/// short rows, which is the physical mechanism behind the paper's
/// d = 9.35 row-split/merge crossover (calibrated to land there).
const GATHER_ISSUE: f64 = 12.0;

/// Issue cost of the merge kernel's per-element segmented machinery
/// (CSR→COO flatten lookup, head-flag computation, smem segmented scan,
/// multi-CTA row writes), in FMA-lane equivalents per element-column.
/// Row-split amortizes all of this across a register-resident row; merge
/// pays it per nonzero — the paper's "merge path has more overhead than
/// row split" (§5.3), calibrated to its Fig. 6a merge-vs-csrmm2 levels
/// (merge's long-row asymptote sits below csrmm2, as the paper measures).
/// Side effect: the Fig. 7 SpMM/GEMM crossover lands near 3–4 % instead
/// of the paper's 9 % — recorded in EXPERIMENTS.md.
const SCAN_ISSUE: f64 = 35.0;

/// Type-1 cap: real kernels bound the damage of one pathological slot
/// (tail CTAs finish and the SM picks up queued work; the cyclic-slot
/// model over-serializes beyond this).  Calibrated so peak suite speedups
/// land near the paper's 4.1× rather than unbounded.
const TYPE1_CAP: f64 = 3.0;

/// Type-1 imbalance of a row-granular decomposition: assign work quanta
/// cyclically to SM warp slots and compare max vs mean *active*-slot work.
/// (Starvation from having fewer units than slots is occupancy's job in
/// [`super::gpu::simulate`]; this measures work-variance only.)
fn type1_over_slots(work_per_unit: impl Iterator<Item = usize>, slots: usize) -> f64 {
    let work: Vec<usize> = work_per_unit.collect();
    let slots = slots.clamp(1, work.len().max(1));
    let mut slot_work = vec![0u64; slots];
    let mut total = 0u64;
    for (i, &w) in work.iter().enumerate() {
        slot_work[i % slots] += w as u64;
        total += w as u64;
    }
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / slots as f64;
    let max = *slot_work.iter().max().unwrap() as f64;
    (max / mean).clamp(1.0, TYPE1_CAP)
}

/// A simulated SpMM kernel: name + model function.
pub struct SpmmModel {
    pub name: &'static str,
    model: fn(&Csr, usize, &GpuSpec) -> WorkEstimate,
}

impl SpmmModel {
    pub fn simulate(&self, a: &Csr, n: usize, gpu: &GpuSpec) -> KernelReport {
        simulate(self.name, &(self.model)(a, n, gpu), gpu)
    }
}

// ---------------------------------------------------------------- row-split

fn rowsplit_estimate(a: &Csr, n: usize, gpu: &GpuSpec) -> WorkEstimate {
    let nnz = a.nnz() as f64;
    let bs = 32.0; // warp batch over the row
    // batches per row: ceil(len/32), min 1 for non-empty rows
    let mut batches = 0.0f64;
    let mut useful = 0.0f64;
    for i in 0..a.m {
        let len = a.row_len(i) as f64;
        if len > 0.0 {
            batches += (len / bs).ceil();
            useful += len;
        }
    }
    let warp_eff = if batches > 0.0 {
        (useful / (batches * bs)).clamp(0.01, 1.0)
    } else {
        1.0
    };
    let rf = b_reuse(a);
    // Memory: A stream + one n-wide B-row gather per true nonzero + C once.
    // Dummy lanes (rows shorter than the 32 batch) broadcast-load B row 0,
    // which coalesces to a single cached transaction — nearly free on the
    // memory side.  Their cost is *issue slots*: gathers are charged at
    // batch granularity below.
    let bytes = nnz * 8.0 + nnz * n as f64 * 4.0 / rf + (a.m * n) as f64 * 4.0;
    // ILP: each warp issues min(len,32) independent B-row gathers per batch.
    let d = a.mean_row_length();
    let ilp = d.min(32.0).max(1.0);
    // Type-1: warps (rows) land on SM warp slots cyclically; slot work =
    // row batches.
    let slots = gpu.sms * (gpu.max_warps_per_sm / 2); // 64-reg kernel → half residency
    let type1 = type1_over_slots(
        (0..a.m).map(|i| a.row_len(i).div_ceil(32).max(1)),
        slots,
    );
    WorkEstimate {
        flops: 2.0 * nnz * n as f64,
        // FMA per useful element + gather issue at *batch* granularity
        // (padded lanes occupy LD/ST slots — the Type-2 cost).
        lane_instrs: nnz * n as f64 * 1.1 + batches * bs * n as f64 * GATHER_ISSUE,
        bytes,
        warps: a.m as f64 * (n as f64 / 32.0).max(1.0),
        warp_efficiency: warp_eff,
        ilp,
        regs_per_thread: 64, // Table 1
        type1,
        launches: 1,
        mem_efficiency: 0.85,
    }
}

pub fn rowsplit_model() -> SpmmModel {
    SpmmModel {
        name: "rowsplit",
        model: rowsplit_estimate,
    }
}

// --------------------------------------------------------------- merge-based

fn merge_estimate(a: &Csr, n: usize, _gpu: &GpuSpec) -> WorkEstimate {
    let nnz = a.nnz() as f64;
    let cta = 128.0; // paper's B
    let t = 1.0; // paper's T for SpMM
    let ctas = (nnz / (cta * t)).ceil().max(1.0);
    let rf = b_reuse(a);
    // Phase-1 partition search + row_ptr staging, flat A stream, B gathers,
    // C writes, carry-out write/read per CTA, plus the Table-1 memory
    // access overhead ncols·nnz/(B·T) (4 B accesses) — the §4.2 cost that
    // scales with B.ncols and forces T = 1.
    let bytes = (a.m + 1) as f64 * 4.0            // row_ptr (partition + staging)
        + nnz * 8.0                                // A col+val
        + nnz * n as f64 * 4.0 / rf                // B gathers (coalesced)
        + (a.m * n) as f64 * 4.0                   // C
        + ctas * n as f64 * 4.0 * 2.0              // carry-out write + fix-up read
        + n as f64 * nnz / (cta * t) * 4.0; // Table-1 overhead
    WorkEstimate {
        flops: 2.0 * nnz * n as f64,
        // FMA + flat gather issue (no padding) + per-element segmented
        // machinery (see SCAN_ISSUE)
        lane_instrs: nnz * n as f64 * (1.1 + GATHER_ISSUE + SCAN_ISSUE),
        bytes,
        warps: ctas * (cta / 32.0) * (n as f64 / 32.0).max(1.0),
        warp_efficiency: 1.0, // flat nonzero stream: no divergence
        ilp: 32.0,
        regs_per_thread: 64, // Table 1 (T=1)
        type1: 1.0,          // equal-nnz by construction
        launches: 3,         // partition, main, fix-up
        mem_efficiency: 0.85,
    }
}

pub fn merge_model() -> SpmmModel {
    SpmmModel {
        name: "merge",
        model: merge_estimate,
    }
}

// ------------------------------------------------------------------- csrmm

/// Divergence of thread-per-row execution: warps of 32 consecutive rows
/// run at the speed of their longest row.
fn thread_per_row_eff(a: &Csr) -> (f64, f64) {
    // returns (warp_efficiency, padded_work_factor)
    let mut useful = 0.0f64;
    let mut padded = 0.0f64;
    for g in (0..a.m).step_by(32) {
        let hi = (g + 32).min(a.m);
        let maxlen = (g..hi).map(|i| a.row_len(i)).max().unwrap_or(0) as f64;
        let sum: usize = (g..hi).map(|i| a.row_len(i)).sum();
        useful += sum as f64;
        padded += maxlen * 32.0;
    }
    if padded == 0.0 {
        (1.0, 1.0)
    } else {
        ((useful / padded).clamp(0.01, 1.0), padded / useful.max(1.0))
    }
}

fn csrmm_estimate(a: &Csr, n: usize, gpu: &GpuSpec) -> WorkEstimate {
    let nnz = a.nnz() as f64;
    let (warp_eff, pad) = thread_per_row_eff(a);
    let rf = b_reuse(a);
    // Column-major B: each lane's gather is strided by k → uncoalesced,
    // captured by the 0.25 achieved-bandwidth constant (not double-counted
    // in bytes).
    let bytes = nnz * 8.0 + nnz * n as f64 * 4.0 / rf + (a.m * n) as f64 * 4.0;
    let warps = (a.m as f64 / 32.0).ceil();
    let slots = gpu.sms * gpu.max_warps_per_sm;
    let type1 = type1_over_slots(
        (0..a.m).step_by(32).map(|g| {
            let hi = (g + 32).min(a.m);
            (g..hi).map(|i| a.row_len(i)).max().unwrap_or(0)
        }),
        slots,
    );
    WorkEstimate {
        flops: 2.0 * nnz * n as f64,
        // thread-per-row: divergence pads every lane to the warp's longest
        // row (the ×pad factor)
        lane_instrs: nnz * n as f64 * (1.1 + GATHER_ISSUE) * pad.min(3.0),
        bytes,
        warps,
        warp_efficiency: warp_eff,
        ilp: (n as f64 / 8.0).clamp(1.0, 4.0), // serial row walk, some j-loop overlap
        regs_per_thread: 32,
        type1,
        launches: 1,
        mem_efficiency: 0.25,
    }
}

pub fn csrmm_model() -> SpmmModel {
    SpmmModel {
        name: "csrmm",
        model: csrmm_estimate,
    }
}

// ------------------------------------------------------------------ csrmm2

fn csrmm2_estimate(a: &Csr, n: usize, gpu: &GpuSpec) -> WorkEstimate {
    let nnz = a.nnz() as f64;
    let (warp_eff, pad) = thread_per_row_eff(a);
    let rf = b_reuse(a);
    // Row-major B (coalesced via smem staging); column-major C is
    // csrmm2's *native* output layout, so its write is coalesced (it is
    // OUR kernels that would pay to emit column-major — §5.2's 3-4 GFlops
    // note).
    let bytes = nnz * 8.0 + nnz * n as f64 * 4.0 / rf + (a.m * n) as f64 * 4.0;
    // threads tile (row × 4-wide column tile)
    let warps = (a.m as f64 / 32.0).ceil() * (n as f64 / 4.0).max(1.0);
    let slots = gpu.sms * ((gpu.max_warps_per_sm as f64 * 0.67) as usize);
    let type1 = type1_over_slots(
        (0..a.m).step_by(32).map(|g| {
            let hi = (g + 32).min(a.m);
            (g..hi).map(|i| a.row_len(i)).max().unwrap_or(0)
        }),
        slots,
    );
    WorkEstimate {
        flops: 2.0 * nnz * n as f64,
        // smem staging adds instruction overhead; divergence pads lanes
        lane_instrs: nnz * n as f64 * (1.4 + GATHER_ISSUE) * pad.min(3.0),
        bytes,
        warps,
        warp_efficiency: warp_eff,
        ilp: 4.0, // column tiling gives modest overlap
        regs_per_thread: 48,
        type1,
        launches: 1,
        mem_efficiency: 0.62,
    }
}

pub fn csrmm2_model() -> SpmmModel {
    SpmmModel {
        name: "csrmm2",
        model: csrmm2_estimate,
    }
}

// ------------------------------------------------------------------ SELL-P

fn sellp_estimate(a: &Csr, n: usize, gpu: &GpuSpec) -> WorkEstimate {
    let nnz = a.nnz() as f64;
    let s = SellP::from_csr(a, 8, 4);
    let stored = *s.slice_ptr.last().unwrap_or(&0) as f64;
    let pad_factor = if nnz > 0.0 { stored / nnz } else { 1.0 };
    let rf = b_reuse(a);
    // Padded entries are loaded and multiplied; lane gathers are
    // slice-local (partially coalesced → 0.70 achieved bandwidth).
    let bytes = stored * 8.0 + stored * n as f64 * 4.0 / rf + (a.m * n) as f64 * 4.0;
    let warps = (s.num_slices() as f64) * (n as f64 / 32.0).max(1.0);
    let slots = gpu.sms * gpu.max_warps_per_sm / 2;
    let type1 = type1_over_slots(
        (0..s.num_slices()).map(|i| s.slice_width[i] * s.slice_height),
        slots,
    );
    WorkEstimate {
        flops: 2.0 * nnz * n as f64,
        // padded entries occupy full FMA + gather issue slots
        lane_instrs: stored * n as f64 * (1.2 + GATHER_ISSUE),
        bytes,
        warps,
        warp_efficiency: (1.0 / pad_factor).clamp(0.01, 1.0),
        ilp: 8.0,
        regs_per_thread: 48,
        type1,
        launches: 1,
        mem_efficiency: 0.70,
    }
}

pub fn sellp_model() -> SpmmModel {
    SpmmModel {
        name: "sellp",
        model: sellp_estimate,
    }
}

// ------------------------------------------------------------------- GEMM

/// Dense `cuBLAS sgemm`-like baseline for Fig. 7: `C[m×n] = A[m×k]·B[k×n]`
/// with A treated dense.
pub fn gemm_model(m: usize, k: usize, n: usize, gpu: &GpuSpec) -> KernelReport {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    // well-tiled dense kernel: each operand streamed ~1.2×
    let bytes = ((m * k + k * n + m * n) as f64) * 4.0 * 1.2;
    let w = WorkEstimate {
        flops,
        // cuBLAS achieves ~75 % of peak on K40 sgemm — model as extra
        // issue slots
        lane_instrs: flops / 2.0 * (1.0 / 0.75),
        bytes,
        warps: (m as f64 / 64.0).max(1.0) * (n as f64 / 64.0).max(1.0) * 8.0,
        warp_efficiency: 1.0,
        ilp: 8.0,
        regs_per_thread: 64,
        type1: 1.0,
        launches: 1,
        mem_efficiency: 0.90,
    };
    simulate("sgemm", &w, gpu)
}

// ------------------------------------------------------------------- SpMV

/// cuSPARSE-csrmv-like (CSR-vector, warp per row) for the Fig. 1 SpMV
/// curve.
pub fn cusparse_spmv_model(a: &Csr, gpu: &GpuSpec) -> KernelReport {
    let nnz = a.nnz() as f64;
    let mut batches = 0.0f64;
    let mut useful = 0.0f64;
    for i in 0..a.m {
        let len = a.row_len(i) as f64;
        if len > 0.0 {
            batches += (len / 32.0).ceil();
            useful += len;
        }
    }
    let warp_eff = if batches > 0.0 {
        (useful / (batches * 32.0)).clamp(0.01, 1.0)
    } else {
        1.0
    };
    let slots = gpu.sms * gpu.max_warps_per_sm;
    let type1 = type1_over_slots((0..a.m).map(|i| a.row_len(i).div_ceil(32).max(1)), slots);
    let w = WorkEstimate {
        flops: 2.0 * nnz,
        lane_instrs: batches * 32.0 * (1.1 + GATHER_ISSUE),
        bytes: nnz * 8.0 + nnz * 4.0 * 4.0 + a.m as f64 * 4.0, // x gathers ~sector waste
        warps: a.m as f64,
        warp_efficiency: warp_eff,
        ilp: 1.0, // Table 1: SpMV row-split has 1 independent x-load
        regs_per_thread: 24,
        type1,
        launches: 1,
        mem_efficiency: 0.70,
    };
    simulate("cusparse_spmv", &w, gpu)
}

/// Our row-split SpMV (Fig. 1 companion; Table-1 SpMV column).
pub fn rowsplit_spmv_model(a: &Csr, gpu: &GpuSpec) -> KernelReport {
    let r = cusparse_spmv_model(a, gpu);
    // identical structure — the paper's own SpMV is not a contribution;
    // reuse with our streaming efficiency
    KernelReport {
        name: "rowsplit_spmv",
        ..r
    }
}

// Convenience: evaluate the default zoo used by the figure harnesses.
/// All five SpMM models in Fig. 5's comparison order.
pub fn all_spmm_models() -> Vec<SpmmModel> {
    vec![
        rowsplit_model(),
        merge_model(),
        csrmm_model(),
        csrmm2_model(),
        sellp_model(),
    ]
}

/// Work decomposition sanity helper (used in tests): batches assigned to
/// SM slots by the row-split model.
pub fn rowsplit_type1(a: &Csr, gpu: &GpuSpec) -> f64 {
    let segs = RowSplit::default().partition(a, gpu.sms * 32);
    crate::loadbalance::rowsplit::type1_imbalance(&segs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;

    fn k40c() -> GpuSpec {
        GpuSpec::k40c()
    }

    #[test]
    fn long_rows_rowsplit_beats_baselines() {
        // Fig. 5(a) regime: d ≈ 62.5
        let g = k40c();
        let a = gen::uniform_rows(8192, 62, Some(4096), 901);
        let rs = rowsplit_model().simulate(&a, 64, &g);
        let mm2 = csrmm2_model().simulate(&a, 64, &g);
        let mm = csrmm_model().simulate(&a, 64, &g);
        let sp = sellp_model().simulate(&a, 64, &g);
        assert!(rs.gflops > mm2.gflops, "rs {} vs mm2 {}", rs.gflops, mm2.gflops);
        assert!(rs.gflops > mm.gflops);
        assert!(rs.gflops > sp.gflops);
        // csrmm (column-major B) clearly worst of the vendor pair
        assert!(mm2.gflops > mm.gflops);
    }

    #[test]
    fn short_irregular_merge_beats_all() {
        // Fig. 5(b) regime: short, irregular rows
        let g = k40c();
        let a = gen::power_law(20_000, 1.1, 2000, 903);
        assert!(a.mean_row_length() < 12.0, "d = {}", a.mean_row_length());
        let mg = merge_model().simulate(&a, 64, &g);
        let rs = rowsplit_model().simulate(&a, 64, &g);
        let mm2 = csrmm2_model().simulate(&a, 64, &g);
        assert!(mg.gflops > rs.gflops, "mg {} vs rs {}", mg.gflops, rs.gflops);
        assert!(mg.gflops > mm2.gflops);
    }

    #[test]
    fn merge_overhead_on_regular_long_rows() {
        // §5.3: merge-path "tends to be lower than row split" when
        // balance isn't needed
        let g = k40c();
        let a = gen::uniform_rows(8192, 64, Some(4096), 905);
        let mg = merge_model().simulate(&a, 64, &g);
        let rs = rowsplit_model().simulate(&a, 64, &g);
        assert!(rs.gflops > mg.gflops, "rs {} vs mg {}", rs.gflops, mg.gflops);
    }

    #[test]
    fn type2_divergence_reported() {
        let g = k40c();
        // short rows: row-split warp efficiency collapses (Fig. 1)
        let short = gen::uniform_rows(100_000, 2, Some(1024), 907);
        let r = rowsplit_model().simulate(&short, 64, &g);
        assert!(r.warp_efficiency < 0.1, "eff = {}", r.warp_efficiency);
        // merge stays at 1.0
        let m = merge_model().simulate(&short, 64, &g);
        assert!((m.warp_efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy_matches_table1_registers() {
        let g = k40c();
        let a = gen::uniform_rows(100_000, 16, Some(1024), 909);
        let r = rowsplit_model().simulate(&a, 64, &g);
        // 64 regs/thread → 32 of 64 warps → 0.5
        assert!((r.occupancy - 0.5).abs() < 1e-6);
    }

    #[test]
    fn starvation_at_tiny_row_counts() {
        // Fig. 1 left edge: 2 rows × 8.3M nnz → SpMM starves
        let g = k40c();
        let a = gen::uniform_rows(2, 100_000, Some(200_000), 911);
        let few = rowsplit_model().simulate(&a, 64, &g);
        let b = gen::uniform_rows(4096, 64, Some(8192), 912);
        let many = rowsplit_model().simulate(&b, 64, &g);
        assert!(many.gflops > 3.0 * few.gflops);
        assert!(few.occupancy < 0.02);
    }

    #[test]
    fn gemm_near_compute_roofline() {
        let g = k40c();
        let r = gemm_model(4096, 4096, 64, &g);
        assert!(!r.memory_bound);
        // ~75 % of 4.29 TF
        assert!(r.gflops > 2000.0 && r.gflops < 4290.0, "gemm {}", r.gflops);
    }

    #[test]
    fn fig7_crossover_between_2_and_20_percent() {
        let g = k40c();
        let (m, k, n) = (4096, 4096, 64);
        let gemm_t = gemm_model(m, k, n, &g).time_s;
        let mut crossover = None;
        for pct in 1..=30 {
            let density = pct as f64 / 100.0;
            let a = gen::fixed_density(m, k, density, 913 + pct as u64);
            let t = merge_model().simulate(&a, n, &g).time_s;
            if t > gemm_t {
                crossover = Some(pct);
                break;
            }
        }
        let c = crossover.expect("no crossover found below 30%");
        assert!(
            (2..=20).contains(&c),
            "crossover at {c}% (paper: 9%)"
        );
    }

    #[test]
    fn scale_free_type1_visible_in_rowsplit() {
        let g = k40c();
        let a = gen::power_law(30_000, 1.05, 5000, 915);
        let rs = rowsplit_model().simulate(&a, 64, &g);
        let mg = merge_model().simulate(&a, 64, &g);
        assert!(rs.type1_imbalance > 1.5, "t1 = {}", rs.type1_imbalance);
        assert!((mg.type1_imbalance - 1.0).abs() < 1e-9);
    }
}
