//! Table 1 — the paper's analytic ILP / register / overhead model.
//!
//! For each (operation × algorithm) cell, the number of *independent
//! instructions per GPU thread*, the register usage, and the extra memory
//! accesses relative to row-split.  The defaults in the paper (shown in
//! brackets in Table 1) are `T = 7` for merge-SpMV, `T = 1` for
//! merge-SpMM, CTA size `B = 128`; these are reproduced by
//! [`Table1::paper_defaults`] and pinned by tests.

/// Tuning parameters of the analytic model.
#[derive(Debug, Clone, Copy)]
pub struct IlpParams {
    /// work items per thread (merge-based T)
    pub t: usize,
    /// CTA size (threads)
    pub cta: usize,
    /// dense-matrix columns (SpMM n); 1 for SpMV
    pub ncols: usize,
}

/// One Table-1 column: the per-thread instruction counts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IlpAnalysis {
    /// independent reads of A.col_ind/A.val per thread
    pub read_a: usize,
    /// independent reads of x (SpMV) or B (SpMM) per thread
    pub read_b: usize,
    /// independent writes of y / C per thread
    pub write_c: usize,
    /// registers per thread
    pub registers: usize,
    /// extra global memory accesses vs row-split, as a function of nnz
    /// (returns the count for a given nnz)
    pub overhead_num: f64,
    pub overhead_den: f64,
}

impl IlpAnalysis {
    /// Extra memory accesses for a matrix with `nnz` nonzeros.
    pub fn overhead(&self, nnz: usize) -> f64 {
        if self.overhead_den == 0.0 {
            return 0.0;
        }
        self.overhead_num * nnz as f64 / self.overhead_den
    }
}

/// SpMV row-split column: 1 independent instruction everywhere, 2 regs.
pub fn spmv_rowsplit() -> IlpAnalysis {
    IlpAnalysis {
        read_a: 1,
        read_b: 1,
        write_c: 1,
        registers: 2,
        overhead_num: 0.0,
        overhead_den: 0.0,
    }
}

/// SpMV merge-based column: T of everything, 2T regs, partition overhead
/// nnz/(B·T).
pub fn spmv_merge(p: IlpParams) -> IlpAnalysis {
    IlpAnalysis {
        read_a: p.t,
        read_b: p.t,
        write_c: p.t,
        registers: 2 * p.t,
        overhead_num: 1.0,
        overhead_den: (p.cta * p.t) as f64,
    }
}

/// SpMM row-split column: reading A is 1; B reads are L (row length mod
/// batch, up to 32) independent coalesced loads; 64 registers to hold the
/// 32-wide accumulator pair.
pub fn spmm_rowsplit(row_len_mod: usize) -> IlpAnalysis {
    let l = if row_len_mod == 0 {
        32
    } else {
        row_len_mod.min(32)
    };
    IlpAnalysis {
        read_a: 1,
        read_b: l,
        write_c: 1,
        registers: 64,
        overhead_num: 0.0,
        overhead_den: 0.0,
    }
}

/// SpMM merge-based column: 32T B-reads/C-writes, 64T registers, overhead
/// ncols·nnz/(B·T) — the carry-out traffic that scales with B.ncols (§4.2).
pub fn spmm_merge(p: IlpParams) -> IlpAnalysis {
    IlpAnalysis {
        read_a: p.t,
        read_b: 32 * p.t,
        write_c: 32 * p.t,
        registers: 64 * p.t,
        overhead_num: p.ncols as f64,
        overhead_den: (p.cta * p.t) as f64,
    }
}

/// The four Table-1 columns with the paper's defaults.
#[derive(Debug, Clone)]
pub struct Table1 {
    pub spmv_rowsplit: IlpAnalysis,
    pub spmv_merge: IlpAnalysis,
    pub spmm_rowsplit: IlpAnalysis,
    pub spmm_merge: IlpAnalysis,
}

impl Table1 {
    /// Paper defaults: T=7 (SpMV), T=1 (SpMM), B=128, ncols=64… the table
    /// itself uses ncols generic; the bracketed overhead `2·A.nnz` comes
    /// from ncols=64? No — from ncols·nnz/(B·T) with B=128, T=1, ncols=256?
    /// The paper brackets `(2A.nnz)` for SpMM merge overhead, i.e.
    /// ncols/(B·T) = 2 with B=128, T=1 ⇒ ncols = 256 columns… but its
    /// bench uses n=64; we pin the *formula*, and the bracketed instance
    /// with ncols=256 as printed.
    pub fn paper_defaults() -> Self {
        Self {
            spmv_rowsplit: spmv_rowsplit(),
            spmv_merge: spmv_merge(IlpParams {
                t: 7,
                cta: 128,
                ncols: 1,
            }),
            spmm_rowsplit: spmm_rowsplit(32),
            spmm_merge: spmm_merge(IlpParams {
                t: 1,
                cta: 128,
                ncols: 256,
            }),
        }
    }

    /// Render the table as aligned text rows (the `table1` bench target).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "operation                 | SpMV row-split | SpMV merge | SpMM row-split | SpMM merge\n",
        );
        let rows = [
            (
                "read A.col_ind & A.val",
                self.spmv_rowsplit.read_a,
                self.spmv_merge.read_a,
                self.spmm_rowsplit.read_a,
                self.spmm_merge.read_a,
            ),
            (
                "read x / read B",
                self.spmv_rowsplit.read_b,
                self.spmv_merge.read_b,
                self.spmm_rowsplit.read_b,
                self.spmm_merge.read_b,
            ),
            (
                "write y / write C",
                self.spmv_rowsplit.write_c,
                self.spmv_merge.write_c,
                self.spmm_rowsplit.write_c,
                self.spmm_merge.write_c,
            ),
            (
                "register usage",
                self.spmv_rowsplit.registers,
                self.spmv_merge.registers,
                self.spmm_rowsplit.registers,
                self.spmm_merge.registers,
            ),
        ];
        for (name, a, b, c, d) in rows {
            s.push_str(&format!("{name:<26}| {a:<15}| {b:<11}| {c:<15}| {d}\n"));
        }
        s.push_str(&format!(
            "mem overhead (nnz=896)    | {:<15}| {:<11.0}| {:<15}| {:.0}\n",
            0,
            self.spmv_merge.overhead(896),
            0,
            self.spmm_merge.overhead(896),
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_table1() {
        let t = Table1::paper_defaults();
        // SpMV row-split: 1/1/1, 2 regs, 0 overhead
        assert_eq!(t.spmv_rowsplit.read_a, 1);
        assert_eq!(t.spmv_rowsplit.registers, 2);
        assert_eq!(t.spmv_rowsplit.overhead(896), 0.0);
        // SpMV merge T=7: 7/7/7, 14 regs, nnz/896 overhead
        assert_eq!(t.spmv_merge.read_a, 7);
        assert_eq!(t.spmv_merge.registers, 14);
        assert!((t.spmv_merge.overhead(896) - 1.0).abs() < 1e-12);
        // SpMM row-split: 1 A-read, 32 B-reads (default L), 64 regs
        assert_eq!(t.spmm_rowsplit.read_a, 1);
        assert_eq!(t.spmm_rowsplit.read_b, 32);
        assert_eq!(t.spmm_rowsplit.write_c, 1);
        assert_eq!(t.spmm_rowsplit.registers, 64);
        // SpMM merge T=1: 1/32/32, 64 regs, 2·nnz overhead (bracketed)
        assert_eq!(t.spmm_merge.read_a, 1);
        assert_eq!(t.spmm_merge.read_b, 32);
        assert_eq!(t.spmm_merge.write_c, 32);
        assert_eq!(t.spmm_merge.registers, 64);
        assert!((t.spmm_merge.overhead(896) - 2.0 * 896.0).abs() < 1e-9);
    }

    #[test]
    fn row_length_sensitivity() {
        // L = 33 → effective reads 1 (33 mod 32), the Type-2 penalty case
        assert_eq!(spmm_rowsplit(33 % 32).read_b, 1);
        // L divides 32 → full 32 independent loads
        assert_eq!(spmm_rowsplit(64 % 32).read_b, 32);
    }

    #[test]
    fn render_contains_all_rows() {
        let s = Table1::paper_defaults().render();
        for needle in [
            "read A.col_ind",
            "read x / read B",
            "write y / write C",
            "register usage",
            "mem overhead",
        ] {
            assert!(s.contains(needle), "missing {needle}");
        }
    }

    #[test]
    fn overhead_scales_with_ncols() {
        let small = spmm_merge(IlpParams {
            t: 1,
            cta: 128,
            ncols: 4,
        });
        let large = spmm_merge(IlpParams {
            t: 1,
            cta: 128,
            ncols: 32,
        });
        // §4.2: carry-out traffic scales with B.ncols
        assert!(large.overhead(1000) > small.overhead(1000) * 7.9);
    }
}
