//! Baseline SpMM implementations the paper compares against (§5, Fig. 5–7).
//!
//! * [`csrmm`] — models cuSPARSE `csrmm`: **column-major** B and C, one
//!   scalar "thread" per row.  Accesses into B are strided (the
//!   uncoalesced pattern the paper's Fig. 3 analysis identifies as the
//!   baseline's weakness).
//! * [`csrmm2`] — models cuSPARSE `csrmm2`: row-major B, column-major C
//!   output.
//! * [`sellp_spmm`] — the MAGMA SELL-P kernel shape: slice-wise ELL walks.
//!
//! On the CPU these differ from the paper's kernels in loop order and
//! stride (reuse and vectorization), mirroring — at cache-line rather than
//! transaction granularity — the coalescing differences the [`crate::sim`]
//! cost model charges for explicitly.

use crate::formats::{Csr, SellP};

use super::rowsplit::effective_workers;

/// cuSPARSE-csrmm-like: B is `k×n` **column-major**, returns C `m×n`
/// **column-major**.  Per row, per nonzero, B is walked with stride k —
/// the unfriendly access pattern.
pub fn csrmm(a: &Csr, b_colmajor: &[f32], n: usize, p: usize) -> Vec<f32> {
    assert_eq!(b_colmajor.len(), a.k * n);
    let p = effective_workers(p, a.m);
    let mut c = vec![0.0f32; a.m * n]; // column-major: c[j*m + i]
    if a.m == 0 || n == 0 {
        return c;
    }
    let rows_per = a.m.div_ceil(p);
    // Column-major C cannot be split into contiguous per-worker row chunks;
    // hand out column panels instead and have every worker walk all rows —
    // the "n independent SpMVs" structure of csrmm.
    std::thread::scope(|scope| {
        let mut rest: &mut [f32] = &mut c;
        let _ = rows_per;
        let cols_per = n.div_ceil(p).max(1);
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + cols_per).min(n);
            let (chunk, tail) = rest.split_at_mut((j1 - j0) * a.m);
            rest = tail;
            scope.spawn(move || {
                for (jj, j) in (j0..j1).enumerate() {
                    let bcol = &b_colmajor[j * a.k..(j + 1) * a.k];
                    let ccol = &mut chunk[jj * a.m..(jj + 1) * a.m];
                    for i in 0..a.m {
                        let (cols, vals) = a.row(i);
                        let mut acc = 0.0f32;
                        for (&cidx, &v) in cols.iter().zip(vals) {
                            acc += v * bcol[cidx as usize];
                        }
                        ccol[i] = acc;
                    }
                }
            });
            j0 = j1;
        }
    });
    c
}

/// cuSPARSE-csrmm2-like: B is `k×n` **row-major**, returns C `m×n`
/// **column-major** (the transpose-on-write the paper measured as a
/// 3–4 GFlops loss for its own kernels).
pub fn csrmm2(a: &Csr, b_rowmajor: &[f32], n: usize, p: usize) -> Vec<f32> {
    assert_eq!(b_rowmajor.len(), a.k * n);
    let p = effective_workers(p, a.m);
    let mut c = vec![0.0f32; a.m * n]; // column-major
    if a.m == 0 || n == 0 {
        return c;
    }
    // Row-parallel compute into a row-major scratch, then transpose on
    // write — mirrors csrmm2's internal tiling + transposed output.
    let scratch = super::rowsplit::rowsplit_spmm(a, b_rowmajor, n, p);
    for i in 0..a.m {
        for j in 0..n {
            c[j * a.m + i] = scratch[i * n + j];
        }
    }
    c
}

/// MAGMA-SELL-P-like SpMM: B row-major, C row-major.  Walks each slice
/// position-major (the GPU lane order), so short slices skip padding work
/// only at slice granularity.
pub fn sellp_spmm(s: &SellP, b: &[f32], n: usize, p: usize) -> Vec<f32> {
    assert_eq!(b.len(), s.k * n);
    let mut c = vec![0.0f32; s.m * n];
    if s.m == 0 || n == 0 {
        return c;
    }
    let num_slices = s.num_slices();
    let p = effective_workers(p, num_slices);
    let slices_per = num_slices.div_ceil(p).max(1);
    std::thread::scope(|scope| {
        let mut rest: &mut [f32] = &mut c;
        let mut sl0 = 0usize;
        while sl0 < num_slices {
            let sl1 = (sl0 + slices_per).min(num_slices);
            let r0 = sl0 * s.slice_height;
            let r1 = (sl1 * s.slice_height).min(s.m);
            let (chunk, tail) = rest.split_at_mut((r1 - r0) * n);
            rest = tail;
            scope.spawn(move || {
                for sl in sl0..sl1 {
                    let rs = sl * s.slice_height;
                    let re = (rs + s.slice_height).min(s.m);
                    let height = re - rs;
                    let base = s.slice_ptr[sl];
                    for pos in 0..s.slice_width[sl] {
                        for r in rs..re {
                            if (pos as u32) >= s.row_len[r] {
                                continue;
                            }
                            let off = base + pos * height + (r - rs);
                            let col = s.col_idx[off] as usize;
                            let v = s.vals[off];
                            let out = &mut chunk[(r - r0) * n..(r - r0 + 1) * n];
                            let brow = &b[col * n..col * n + n];
                            for (o, &bv) in out.iter_mut().zip(brow) {
                                *o += v * bv;
                            }
                        }
                    }
                }
            });
            sl0 = sl1;
        }
    });
    c
}

/// Transpose helpers for layout conversions in tests/benches.
pub fn to_col_major(row_major: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            out[j * rows + i] = row_major[i * cols + j];
        }
    }
    out
}

pub fn to_row_major(col_major: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            out[i * cols + j] = col_major[j * rows + i];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::spmm_reference;

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn csrmm_matches_reference() {
        let a = Csr::random(120, 90, 6.0, 601);
        let b = crate::gen::dense_matrix(90, 12, 602);
        let want = spmm_reference(&a, &b, 12);
        let b_cm = to_col_major(&b, 90, 12);
        let got_cm = csrmm(&a, &b_cm, 12, 4);
        assert_close(&to_row_major(&got_cm, 120, 12), &want);
    }

    #[test]
    fn csrmm2_matches_reference() {
        let a = Csr::random(120, 90, 6.0, 603);
        let b = crate::gen::dense_matrix(90, 12, 604);
        let want = spmm_reference(&a, &b, 12);
        let got_cm = csrmm2(&a, &b, 12, 4);
        assert_close(&to_row_major(&got_cm, 120, 12), &want);
    }

    #[test]
    fn sellp_matches_reference() {
        let a = Csr::random(200, 150, 7.0, 605);
        let b = crate::gen::dense_matrix(150, 8, 606);
        let want = spmm_reference(&a, &b, 8);
        let s = SellP::from_csr(&a, 32, 4);
        assert_close(&sellp_spmm(&s, &b, 8, 4), &want);
    }

    #[test]
    fn sellp_irregular_rows() {
        let a = crate::gen::power_law(500, 1.2, 100, 607);
        let b = crate::gen::dense_matrix(500, 8, 608);
        let want = spmm_reference(&a, &b, 8);
        let s = SellP::from_csr(&a, 8, 1);
        assert_close(&sellp_spmm(&s, &b, 8, 4), &want);
    }

    #[test]
    fn transpose_roundtrip() {
        let x = crate::gen::dense_matrix(7, 5, 609);
        assert_eq!(to_row_major(&to_col_major(&x, 7, 5), 7, 5), x);
    }
}
