//! Dense GEMM baseline — the Fig. 7 `cuBLAS sgemm` stand-in.
//!
//! Blocked, multi-threaded f32 GEMM.  Not trying to be OpenBLAS; trying to
//! be a *fair* dense baseline whose arithmetic throughput is in the same
//! league as the sparse executors so the Fig. 7 crossover is meaningful.

use super::rowsplit::effective_workers;

/// Cache-blocking tile sizes (L1-friendly for f32).
const MC: usize = 64;
const KC: usize = 128;

/// Dense `C[m×n] = A[m×k]·B[k×n]`, all row-major, `p` workers (0 = auto).
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, p: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let p = effective_workers(p, m.div_ceil(MC));
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    // row-panel parallelism: each worker owns full row blocks of C
    let panels: Vec<(usize, usize)> = (0..m.div_ceil(MC))
        .map(|bi| (bi * MC, ((bi + 1) * MC).min(m)))
        .collect();
    let chunks_per = panels.len().div_ceil(p);

    std::thread::scope(|scope| {
        let mut rest: &mut [f32] = &mut c;
        let mut row = 0usize;
        for group in panels.chunks(chunks_per) {
            let r0 = group[0].0;
            let r1 = group.last().unwrap().1;
            debug_assert_eq!(r0, row);
            let (chunk, tail) = rest.split_at_mut((r1 - r0) * n);
            rest = tail;
            row = r1;
            scope.spawn(move || {
                for &(p0, p1) in group {
                    for kb in (0..k).step_by(KC) {
                        let k1 = (kb + KC).min(k);
                        for i in p0..p1 {
                            let arow = &a[i * k..(i + 1) * k];
                            let crow = &mut chunk[(i - r0) * n..(i - r0 + 1) * n];
                            for kk in kb..k1 {
                                let av = arow[kk];
                                if av == 0.0 {
                                    continue;
                                }
                                let brow = &b[kk * n..kk * n + n];
                                for (o, &bv) in crow.iter_mut().zip(brow) {
                                    *o += av * bv;
                                }
                            }
                        }
                    }
                }
            });
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        let (m, k, n) = (130, 70, 20);
        let a = crate::gen::dense_matrix(m, k, 501);
        let b = crate::gen::dense_matrix(k, n, 502);
        let want = naive(&a, &b, m, k, n);
        for p in [1, 2, 4] {
            let got = gemm(&a, &b, m, k, n, p);
            for (x, y) in got.iter().zip(&want) {
                assert!((x - y).abs() < 1e-2 * (1.0 + y.abs()));
            }
        }
    }

    #[test]
    fn degenerate_dims() {
        assert!(gemm(&[], &[], 0, 0, 0, 2).is_empty());
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        // 1×2 · 2×1
        assert_eq!(gemm(&a, &b, 1, 2, 1, 1), vec![11.0]);
    }

    #[test]
    fn identity() {
        let m = 16;
        let mut eye = vec![0.0f32; m * m];
        for i in 0..m {
            eye[i * m + i] = 1.0;
        }
        let b = crate::gen::dense_matrix(m, 8, 503);
        assert_eq!(gemm(&eye, &b, m, m, 8, 2), b);
    }
}
