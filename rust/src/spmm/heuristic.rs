//! The paper's O(1) algorithm-selection heuristic (§5.4).
//!
//! `d = nnz / m` (mean row length): merge-based when `d < 9.35`, row-split
//! otherwise.  The paper reports 99.3 % binary-classification accuracy
//! against an oracle that always picks the faster kernel, and a combined
//! 31.7 % geomean speedup over cuSPARSE csrmm2.

use crate::formats::Csr;

/// The published threshold.
pub const DEFAULT_THRESHOLD: f64 = 9.35;

/// Which SpMM algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    RowSplit,
    MergeBased,
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Algorithm::RowSplit => write!(f, "row-split"),
            Algorithm::MergeBased => write!(f, "merge-based"),
        }
    }
}

/// The mean-row-length selector.
#[derive(Debug, Clone, Copy)]
pub struct Heuristic {
    pub threshold: f64,
}

impl Default for Heuristic {
    fn default() -> Self {
        Self {
            threshold: DEFAULT_THRESHOLD,
        }
    }
}

impl Heuristic {
    pub fn new(threshold: f64) -> Self {
        Self { threshold }
    }

    /// O(1): one division on already-stored quantities.
    pub fn select(&self, a: &Csr) -> Algorithm {
        if a.mean_row_length() < self.threshold {
            Algorithm::MergeBased
        } else {
            Algorithm::RowSplit
        }
    }

    /// Run the selected executor.
    pub fn spmm(&self, a: &Csr, b: &[f32], n: usize, p: usize) -> Vec<f32> {
        match self.select(a) {
            Algorithm::RowSplit => super::rowsplit_spmm(a, b, n, p),
            Algorithm::MergeBased => super::merge_spmm(a, b, n, p),
        }
    }
}

/// Outcome of comparing the heuristic against a timing oracle on one
/// dataset (used by the §5.4 accuracy experiment).
#[derive(Debug, Clone)]
pub struct OracleRecord {
    pub name: String,
    pub d: f64,
    pub t_rowsplit: f64,
    pub t_merge: f64,
    pub picked: Algorithm,
}

impl OracleRecord {
    pub fn oracle(&self) -> Algorithm {
        if self.t_merge < self.t_rowsplit {
            Algorithm::MergeBased
        } else {
            Algorithm::RowSplit
        }
    }

    pub fn heuristic_correct(&self) -> bool {
        self.picked == self.oracle()
    }

    /// Time of the heuristic's pick.
    pub fn t_picked(&self) -> f64 {
        match self.picked {
            Algorithm::RowSplit => self.t_rowsplit,
            Algorithm::MergeBased => self.t_merge,
        }
    }

    /// Time of the oracle's pick.
    pub fn t_oracle(&self) -> f64 {
        self.t_rowsplit.min(self.t_merge)
    }
}

/// Classification accuracy over a set of records (paper: 99.3 %).
pub fn oracle_accuracy(records: &[OracleRecord]) -> f64 {
    if records.is_empty() {
        return 1.0;
    }
    records.iter().filter(|r| r.heuristic_correct()).count() as f64 / records.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_by_mean_row_length() {
        let h = Heuristic::default();
        let short = Csr::random(1000, 1000, 4.0, 701);
        let long = crate::gen::uniform_rows(256, 64, Some(512), 702);
        assert_eq!(h.select(&short), Algorithm::MergeBased);
        assert_eq!(h.select(&long), Algorithm::RowSplit);
    }

    #[test]
    fn threshold_boundary() {
        let a = crate::gen::uniform_rows(100, 9, Some(64), 703); // d = 9 < 9.35
        let b = crate::gen::uniform_rows(100, 10, Some(64), 704); // d = 10 > 9.35
        let h = Heuristic::default();
        assert_eq!(h.select(&a), Algorithm::MergeBased);
        assert_eq!(h.select(&b), Algorithm::RowSplit);
    }

    #[test]
    fn spmm_dispatch_correct() {
        let a = Csr::random(200, 200, 5.0, 705);
        let b = crate::gen::dense_matrix(200, 8, 706);
        let got = Heuristic::default().spmm(&a, &b, 8, 4);
        let want = crate::spmm::spmm_reference(&a, &b, 8);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()));
        }
    }

    #[test]
    fn oracle_record_logic() {
        let r = OracleRecord {
            name: "x".into(),
            d: 5.0,
            t_rowsplit: 2.0,
            t_merge: 1.0,
            picked: Algorithm::MergeBased,
        };
        assert_eq!(r.oracle(), Algorithm::MergeBased);
        assert!(r.heuristic_correct());
        assert_eq!(r.t_picked(), 1.0);
        let wrong = OracleRecord {
            picked: Algorithm::RowSplit,
            ..r.clone()
        };
        assert!(!wrong.heuristic_correct());
        assert_eq!(oracle_accuracy(&[r, wrong]), 0.5);
    }
}
