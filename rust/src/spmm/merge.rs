//! Algorithm II — merge-based SpMM executor (paper §4.2, Algorithm 1).
//!
//! Literal two-phase structure with pool workers as CTAs:
//!
//! * **Phase 1** (`PartitionSpmm`): an equal-nonzero decomposition from
//!   [`crate::loadbalance`] (1-D [`NonzeroSplit`] by default — the paper's
//!   choice — or 2-D [`MergePath`] for the ablation bench).  On the serve
//!   path this phase is computed once per fingerprint and replayed from
//!   the plan cache ([`crate::plan::Planner::partition_for`]).
//! * **Phase 2**: each worker streams its nonzeros, accumulating row
//!   partials.  Rows *fully started* inside the segment are written
//!   directly to C (no other worker touches them); the worker's **first
//!   touched row** may be shared with the previous worker, so its partial
//!   goes to a reusable carry-out slot instead (Algorithm 1, line 22).
//! * **Fix-up** (`FixCarryOut`, line 24): a sequential pass adds each
//!   carry-out into C — "the only way the user can pass information from
//!   one CTA to another".
//!
//! The carry-out traffic is the §4.2 trade-off: it scales with `B.ncols`,
//! which is why the paper keeps T = 1 for SpMM.
//!
//! [`merge_spmm_into`] is the zero-allocation serve path (precomputed
//! partition, pooled threads, reused carry arenas, caller-provided
//! output); [`merge_spmm`] is the classic allocating wrapper over it.

// unsafe surface: per-segment disjoint output windows and carry slots
// handed to pool workers; every site carries a SAFETY contract.
#![allow(unsafe_code)]

use crate::exec::{CarrySlot, ExecCtx, SendPtr, NO_CARRY};
use crate::formats::Csr;
use crate::loadbalance::{MergePath, NonzeroSplit, Partitioner, Segment};

use super::rowsplit::effective_workers;

/// Which phase-1 decomposition to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeKind {
    /// 1-D binary search on row_ptr (Baxter / this paper's SpMM)
    NonzeroSplit,
    /// 2-D diagonal search (Merrill & Garland)
    MergePath,
}

/// Merge-based SpMM: `C = A·B` with `p` parallel workers (0 = auto).
pub fn merge_spmm(a: &Csr, b: &[f32], n: usize, p: usize) -> Vec<f32> {
    merge_spmm_with(a, b, n, p, MergeKind::NonzeroSplit)
}

/// Merge-based SpMM with an explicit phase-1 decomposition.
pub fn merge_spmm_with(a: &Csr, b: &[f32], n: usize, p: usize, kind: MergeKind) -> Vec<f32> {
    assert_eq!(b.len(), a.k * n, "B must be k×n row-major");
    let p = effective_workers(p, a.nnz());
    let mut c = vec![0.0f32; a.m * n];
    if a.m == 0 || n == 0 || a.nnz() == 0 {
        return c;
    }
    let segs: Vec<Segment> = match kind {
        MergeKind::NonzeroSplit => NonzeroSplit.partition(a, p),
        MergeKind::MergePath => MergePath.partition(a, p),
    };
    let mut ctx = ExecCtx::with_global_pool();
    merge_spmm_into(a, b, n, &segs, &mut ctx, &mut c);
    c
}

/// Merge-based SpMM into a caller-provided buffer — the zero-allocation
/// hot path.
///
/// Contract (`debug_assert`ed): `segs` is a nonzero-ordered partition of
/// `a` satisfying [`crate::loadbalance::validate_segments`] (from
/// [`NonzeroSplit`] or [`MergePath`], or replayed through
/// [`crate::exec::partition_matches`]).  `b.len() == a.k * n` and
/// `c.len() == a.m * n`.  `c` is fully overwritten (zeroed, then
/// accumulated).  Steady state performs no heap allocation and no thread
/// creation: carry-out partials live in `ctx`'s reusable slots.
// audit: hot — steady-state kernel; R3 bans allocation/clock tokens here
pub fn merge_spmm_into(
    a: &Csr,
    b: &[f32],
    n: usize,
    segs: &[Segment],
    ctx: &mut ExecCtx,
    c: &mut [f32],
) {
    assert_eq!(b.len(), a.k * n, "B must be k×n row-major");
    assert_eq!(c.len(), a.m * n, "C must be m×n row-major");
    c.fill(0.0);
    if a.m == 0 || n == 0 || a.nnz() == 0 {
        return;
    }
    // Hard assert, not debug: workers write through raw pointers whose
    // disjointness rests on the validate_segments invariants (nz tiling +
    // non-rewind rows ⇒ disjoint own ranges); an invalid partition in
    // release would be UB instead of a panic.  O(p) — noise next to the
    // multiply.
    if let Err(e) = crate::loadbalance::validate_segments(a, segs) {
        panic!("merge_spmm_into: invalid partition: {e}");
    }
    let (pool, carries) = ctx.prepare(segs.len());

    // Phase 2: worker w direct-writes rows (row_start+1, row_end) — its
    // first touched row may be shared with the previous worker and goes to
    // the carry slot.  The validate_segments non-rewind invariant
    // (row_start_i + 1 ≥ row_end_{i-1}) makes the direct-write ranges
    // pairwise disjoint, so C and the carry slots can be handed out as
    // disjoint windows of shared base pointers.
    let c_base = SendPtr(c.as_mut_ptr());
    let carry_base = SendPtr(carries.as_mut_ptr());
    pool.broadcast(segs.len(), &|s| {
        let seg = segs[s];
        let own_start = seg.row_start + 1;
        let own_end = seg.row_end.max(own_start);
        // SAFETY: own ranges are disjoint across tasks (see above) and
        // in-bounds; carry slot `s` is touched by task `s` only.
        // (own_start can be m+1 only for a degenerate tail segment whose
        // own range is empty — clamp the pointer offset, length is 0)
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(
                c_base.0.add(own_start.min(a.m) * n),
                (own_end - own_start) * n,
            )
        };
        // SAFETY: carry slot `s` is in-bounds (`carries.len() == segs.len()`)
        // and written by task `s` alone, so no two tasks alias it.
        let slot = unsafe { &mut *carry_base.0.add(s) };
        worker(a, b, n, seg, own_start, chunk, slot);
    });

    // FixCarryOut: sequential accumulation of shared-row partials.
    for slot in carries.iter() {
        if slot.row == NO_CARRY {
            continue;
        }
        let out = &mut c[slot.row * n..(slot.row + 1) * n];
        for (o, v) in out.iter_mut().zip(&slot.buf) {
            *o += v;
        }
    }
}

/// One CTA's phase-2 work: stream nonzeros `seg.nz_start..seg.nz_end`,
/// write rows `own_start..` into `chunk`, record the first-row partial in
/// the carry slot.
fn worker(
    a: &Csr,
    b: &[f32],
    n: usize,
    seg: Segment,
    own_start: usize,
    chunk: &mut [f32],
    slot: &mut CarrySlot,
) {
    let mut row = seg.row_start;
    let mut nz = seg.nz_start;
    while nz < seg.nz_end {
        // advance to the row containing nz (skips empty rows)
        while row + 1 <= a.m && a.row_ptr[row + 1] <= nz {
            row += 1;
        }
        let row_end_nz = a.row_ptr[row + 1].min(seg.nz_end);
        if row < own_start {
            // first touched row (shared) → accumulate into the carry slot
            if slot.row == NO_CARRY {
                slot.start(row, n);
            }
            accumulate(a, b, n, nz, row_end_nz, &mut slot.buf);
        } else {
            let off = (row - own_start) * n;
            accumulate(a, b, n, nz, row_end_nz, &mut chunk[off..off + n]);
        }
        nz = row_end_nz;
    }
}

/// Flat product loop: out += Σ vals[e]·B[col[e], :] for e in [nz0, nz1).
///
/// §Perf: for n ≤ 64 the partial sum lives in a fixed stack tile (the
/// Table-1 register accumulator) and lands in `out` once — +17 % measured
/// on the single-core testbed (EXPERIMENTS.md §Perf).
#[inline]
fn accumulate(a: &Csr, b: &[f32], n: usize, nz0: usize, nz1: usize, out: &mut [f32]) {
    // Hoist the span slices once: `col_idx`/`vals` live behind a
    // `SharedSlice` window (shard views), so per-element indexing would
    // re-derive the window every nonzero in the innermost loop.
    let cols = &a.col_idx[nz0..nz1];
    let vals = &a.vals[nz0..nz1];
    // tile only pays off when the row segment amortizes its init+writeback
    if n <= 64 && nz1 - nz0 >= 8 {
        let mut acc = [0.0f32; 64];
        for (&col, &v) in cols.iter().zip(vals) {
            let brow = &b[col as usize * n..col as usize * n + n];
            for (o, &bv) in acc[..n].iter_mut().zip(brow) {
                *o += v * bv;
            }
        }
        for (o, &av) in out.iter_mut().zip(&acc[..n]) {
            *o += av;
        }
        return;
    }
    for (&col, &v) in cols.iter().zip(vals) {
        let brow = &b[col as usize * n..col as usize * n + n];
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += v * bv;
        }
    }
}

/// Merge-based SpMV (n = 1 specialization).
pub fn merge_spmv(a: &Csr, x: &[f32], p: usize) -> Vec<f32> {
    merge_spmm(a, x, 1, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::spmm_reference;

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matches_reference_both_kinds() {
        let a = Csr::random(200, 150, 8.0, 401);
        let b = crate::gen::dense_matrix(150, 16, 402);
        let want = spmm_reference(&a, &b, 16);
        for p in [1, 2, 4, 8, 32] {
            for kind in [MergeKind::NonzeroSplit, MergeKind::MergePath] {
                assert_close(&merge_spmm_with(&a, &b, 16, p, kind), &want);
            }
        }
    }

    #[test]
    fn one_giant_row_spanning_all_workers() {
        // the carry-out stress case: one row split across every CTA
        let cols: Vec<u32> = (0..4096).collect();
        let a = Csr::new(1, 4096, vec![0, 4096], cols, vec![1.0; 4096]).unwrap();
        let b = crate::gen::dense_matrix(4096, 8, 403);
        let want = spmm_reference(&a, &b, 8);
        assert_close(&merge_spmm(&a, &b, 8, 16), &want);
    }

    #[test]
    fn many_empty_rows() {
        // the merge-path pathology
        let mut row_ptr = vec![0usize; 5001];
        row_ptr[5000] = 64;
        for v in row_ptr.iter_mut().take(5000).skip(4999) {
            *v = 0;
        }
        // all nonzeros in the last row
        for (i, v) in row_ptr.iter_mut().enumerate() {
            *v = if i == 5000 { 64 } else { 0 };
        }
        let a = Csr::new(5000, 64, row_ptr, (0..64).collect(), vec![1.0; 64]).unwrap();
        let b = crate::gen::dense_matrix(64, 4, 404);
        let want = spmm_reference(&a, &b, 4);
        for kind in [MergeKind::NonzeroSplit, MergeKind::MergePath] {
            assert_close(&merge_spmm_with(&a, &b, 4, 8, kind), &want);
        }
    }

    #[test]
    fn rows_exactly_on_boundaries() {
        // uniform rows that divide the worker count evenly: no sharing
        let a = crate::gen::uniform_rows(64, 16, Some(128), 405);
        let b = crate::gen::dense_matrix(128, 8, 406);
        assert_close(&merge_spmm(&a, &b, 8, 8), &spmm_reference(&a, &b, 8));
    }

    #[test]
    fn short_row_regime() {
        let a = Csr::random(500, 500, 4.0, 407);
        let b = crate::gen::dense_matrix(500, 32, 408);
        assert_close(&merge_spmm(&a, &b, 32, 8), &spmm_reference(&a, &b, 32));
    }

    #[test]
    fn agrees_with_rowsplit() {
        let a = Csr::random(300, 300, 10.0, 409);
        let b = crate::gen::dense_matrix(300, 16, 410);
        assert_close(
            &merge_spmm(&a, &b, 16, 8),
            &crate::spmm::rowsplit_spmm(&a, &b, 16, 8),
        );
    }

    #[test]
    fn empty_matrix() {
        let a = Csr::empty(10, 10);
        let b = crate::gen::dense_matrix(10, 4, 411);
        assert_eq!(merge_spmm(&a, &b, 4, 4), vec![0.0; 40]);
    }

    #[test]
    fn into_reuses_ctx_and_overwrites_stale_data() {
        let a = Csr::random(150, 150, 6.0, 414);
        let b = crate::gen::dense_matrix(150, 12, 415);
        let want = spmm_reference(&a, &b, 12);
        let segs = NonzeroSplit.partition(&a, 6);
        let mut ctx = ExecCtx::with_global_pool();
        let mut c = vec![f32::NAN; 150 * 12];
        for _ in 0..3 {
            merge_spmm_into(&a, &b, 12, &segs, &mut ctx, &mut c);
            assert_close(&c, &want);
        }
    }

    #[test]
    fn spmv() {
        let a = Csr::random(300, 200, 5.0, 412);
        let x = crate::gen::dense_matrix(200, 1, 413);
        assert_close(&merge_spmv(&a, &x, 4), &crate::spmm::spmv_reference(&a, &x));
    }
}
