//! SpMM executors, the heuristic selector, baselines, and the Table-1
//! analytic model.
//!
//! These are the *CPU reference implementations* of the paper's two
//! algorithms: they consume the same [`crate::loadbalance`] decompositions
//! a GPU kernel would, run them across real threads (one thread = one
//! "CTA"), and implement the carry-out fix-up of Algorithm 1 literally.
//! They serve three roles:
//!
//! 1. correctness oracles for the PJRT artifacts (integration tests),
//! 2. the measured substrate for the figure harnesses (real wallclock,
//!    complementing the [`crate::sim`] cost model),
//! 3. the engine's fallback path when a matrix fits no AOT bucket.

pub mod analysis;
pub mod baselines;
pub mod dense;
pub mod heuristic;
pub mod merge;
pub mod rowsplit;

pub use analysis::{IlpAnalysis, Table1};
pub use heuristic::{Algorithm, Heuristic, DEFAULT_THRESHOLD};
pub use merge::{merge_spmm, merge_spmm_into};
pub use rowsplit::{rowsplit_spmm, rowsplit_spmm_into, TILE_WIDTH};

use crate::formats::Csr;

/// Reference (serial, textbook) SpMM used as the ground truth in tests:
/// `C[m×n] = A·B`, B and C dense row-major.
pub fn spmm_reference(a: &Csr, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(b.len(), a.k * n, "B must be k×n row-major");
    let mut c = vec![0.0f32; a.m * n];
    for i in 0..a.m {
        let (cols, vals) = a.row(i);
        let out = &mut c[i * n..(i + 1) * n];
        for (&col, &v) in cols.iter().zip(vals) {
            let brow = &b[col as usize * n..col as usize * n + n];
            for (o, &bv) in out.iter_mut().zip(brow) {
                *o += v * bv;
            }
        }
    }
    c
}

/// Reference SpMV.
pub fn spmv_reference(a: &Csr, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), a.k);
    (0..a.m)
        .map(|i| {
            let (cols, vals) = a.row(i);
            cols.iter()
                .zip(vals)
                .map(|(&c, &v)| v * x[c as usize])
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_small() {
        // [[1,0,2],[0,0,0],[3,4,0]] · [[1,1],[2,2],[3,3]]
        let a = Csr::new(
            3,
            3,
            vec![0, 2, 2, 4],
            vec![0, 2, 0, 1],
            vec![1.0, 2.0, 3.0, 4.0],
        )
        .unwrap();
        let b = vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
        let c = spmm_reference(&a, &b, 2);
        assert_eq!(c, vec![7.0, 7.0, 0.0, 0.0, 11.0, 11.0]);
    }

    #[test]
    fn spmv_matches_spmm_column() {
        let a = Csr::random(50, 40, 5.0, 201);
        let b: Vec<f32> = (0..40).map(|i| i as f32 * 0.1).collect();
        let y = spmv_reference(&a, &b);
        let c = spmm_reference(&a, &b, 1);
        assert_eq!(y, c);
    }
}
