//! Algorithm I — row-split SpMM executor (paper §4.1).
//!
//! One pool worker plays one "warp": it owns a contiguous block of rows
//! (the [`RowSplit`] decomposition) and streams each row's nonzeros in
//! `WARP_BATCH`-wide chunks, exactly the paper's "batches of 32"
//! structure.  The per-chunk inner loop over the dense width `n` is the
//! lane dimension — each iteration is the independent, coalesced B-row
//! load that thread `j` of the warp performs — and is written stride-1
//! over both `B` and `C` rows so the compiler vectorizes it (the CPU
//! analogue of coalescing; see DESIGN.md §Hardware-Adaptation).
//!
//! Two entry layers:
//!
//! * [`rowsplit_spmm_into`] — the zero-allocation serve path: precomputed
//!   partition, caller-provided output, persistent [`ExecCtx`] pool.
//! * [`rowsplit_spmm`] — the classic allocating wrapper (tests, benches,
//!   ad-hoc callers), now a thin shell over `_into` on the process-wide
//!   pool: no per-call thread spawn anywhere.

// unsafe surface: per-segment disjoint output windows handed to pool
// workers; every site carries a SAFETY contract.
#![allow(unsafe_code)]

use crate::exec::{ExecCtx, SendPtr};
use crate::formats::Csr;
use crate::loadbalance::{Partitioner, RowSplit, Segment};

/// The paper's warp width: nonzeros are processed in batches of 32.
pub const WARP_BATCH: usize = 32;

/// Stack-tile width: the register-blocked accumulator covers the dense
/// width in tiles of this many columns (the CPU analogue of the paper's
/// 64-register accumulator, Table 1).
pub const TILE_WIDTH: usize = 64;

/// Row-granularity choice (paper §4.1 design decision 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// one thread per row — wins on very short rows (Fig. 4 left)
    ThreadPerRow,
    /// one warp per row — the paper's default
    WarpPerRow,
}

/// Row-split SpMM: `C = A·B` with `p` parallel workers.
///
/// * `b` is `k×n` row-major, result is `m×n` row-major.
/// * `p = 0` → use available parallelism.
pub fn rowsplit_spmm(a: &Csr, b: &[f32], n: usize, p: usize) -> Vec<f32> {
    rowsplit_spmm_granular(a, b, n, p, Granularity::WarpPerRow)
}

/// Row-split with an explicit granularity (exposed for the Fig. 4 bench).
pub fn rowsplit_spmm_granular(
    a: &Csr,
    b: &[f32],
    n: usize,
    p: usize,
    gran: Granularity,
) -> Vec<f32> {
    assert_eq!(b.len(), a.k * n, "B must be k×n row-major");
    let p = effective_workers(p, a.m);
    let mut c = vec![0.0f32; a.m * n];
    if a.m == 0 || n == 0 {
        return c;
    }
    let segs = RowSplit::default().partition(a, p);
    let mut ctx = ExecCtx::with_global_pool();
    rowsplit_spmm_into_granular(a, b, n, &segs, gran, &mut ctx, &mut c);
    c
}

/// Row-split SpMM into a caller-provided buffer — the zero-allocation hot
/// path.
///
/// Contract (`debug_assert`ed): `segs` is a row partition of `a` (from
/// [`RowSplit`], or replayed through
/// [`crate::exec::partition_matches`]): contiguous row ranges covering
/// `0..a.m` whose nonzero bounds equal the `row_ptr` spans.  `b.len() ==
/// a.k * n` and `c.len() == a.m * n`.  Every element of `c` is
/// overwritten; no heap allocation and no thread creation occur.
// audit: hot — steady-state kernel; R3 bans allocation/clock tokens here
pub fn rowsplit_spmm_into(
    a: &Csr,
    b: &[f32],
    n: usize,
    segs: &[Segment],
    ctx: &mut ExecCtx,
    c: &mut [f32],
) {
    rowsplit_spmm_into_granular(a, b, n, segs, Granularity::WarpPerRow, ctx, c)
}

/// [`rowsplit_spmm_into`] with an explicit granularity.
// audit: hot — steady-state kernel; R3 bans allocation/clock tokens here
pub fn rowsplit_spmm_into_granular(
    a: &Csr,
    b: &[f32],
    n: usize,
    segs: &[Segment],
    gran: Granularity,
    ctx: &mut ExecCtx,
    c: &mut [f32],
) {
    assert_eq!(b.len(), a.k * n, "B must be k×n row-major");
    assert_eq!(c.len(), a.m * n, "C must be m×n row-major");
    if a.m == 0 || n == 0 {
        c.fill(0.0);
        return;
    }
    // Hard asserts, not debug: workers write through raw pointers derived
    // from `segs`, so an invalid partition in release would be UB instead
    // of a panic.  Both checks are O(p) — noise next to the multiply.
    if let Err(e) = crate::loadbalance::validate_segments(a, segs) {
        panic!("rowsplit_spmm_into: invalid partition: {e}");
    }
    let mut next_row = 0usize;
    for s in segs {
        assert_eq!(s.row_start, next_row, "segs must be a contiguous row partition");
        next_row = s.row_end;
    }
    assert_eq!(next_row, a.m, "segs must cover all rows");
    // Segments own disjoint row ranges, so workers write through disjoint
    // windows of one shared base pointer (the split_at_mut argument, made
    // per-task).
    let base = SendPtr(c.as_mut_ptr());
    ctx.pool().broadcast(segs.len(), &|s| {
        let seg = segs[s];
        // SAFETY: row ranges are disjoint across segments and in-bounds
        // (validated above), so this window aliases no other task's.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(
                base.0.add(seg.row_start * n),
                (seg.row_end - seg.row_start) * n,
            )
        };
        for i in seg.row_start..seg.row_end {
            let off = (i - seg.row_start) * n;
            let out = &mut chunk[off..off + n];
            match gran {
                Granularity::WarpPerRow => row_kernel_warp(a, b, n, i, out),
                Granularity::ThreadPerRow => row_kernel_thread(a, b, n, i, out),
            }
        }
    });
}

/// Warp-per-row inner kernel: nonzeros in WARP_BATCH chunks; within a
/// chunk the B-row loads are independent (the ILP Table 1 counts) and the
/// n-wide FMA is the coalesced lane dimension.
///
/// §Perf: the accumulator lives in a fixed-size stack tile (the CPU
/// analogue of the paper's 64-register accumulator, Table 1) so the
/// compiler keeps it in vector registers across the whole row.  For
/// `n > 64` the dense width is walked in [`TILE_WIDTH`]-column tiles —
/// each tile re-streams the row's nonzeros, trading redundant A reads for
/// register-resident accumulation at every width, not just `n ≤ 64`.
#[inline]
fn row_kernel_warp(a: &Csr, b: &[f32], n: usize, i: usize, out: &mut [f32]) {
    let (cols, vals) = a.row(i);
    let mut j = 0usize;
    while j < n {
        let w = (n - j).min(TILE_WIDTH);
        let mut acc = [0.0f32; TILE_WIDTH];
        let mut pos = 0usize;
        while pos < cols.len() {
            let end = (pos + WARP_BATCH).min(cols.len());
            // One "warp batch": up to 32 independent B-row gathers.
            for t in pos..end {
                let col = cols[t] as usize;
                let v = vals[t];
                let brow = &b[col * n + j..col * n + j + w];
                // lane dimension: stride-1 over the tile → vectorized FMA
                for (o, &bv) in acc[..w].iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
            pos = end;
        }
        out[j..j + w].copy_from_slice(&acc[..w]);
        j += w;
    }
}

/// Thread-per-row kernel: a single serial walk (no batching) — models the
/// alternative granularity that wins for very short rows.  Overwrites
/// `out` (zeroes first) so it composes with reused output buffers.
#[inline]
fn row_kernel_thread(a: &Csr, b: &[f32], n: usize, i: usize, out: &mut [f32]) {
    let (cols, vals) = a.row(i);
    out.fill(0.0);
    for (&col, &v) in cols.iter().zip(vals) {
        let brow = &b[col as usize * n..col as usize * n + n];
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += v * bv;
        }
    }
}

/// Row-split SpMV (n = 1 specialization used by the Fig. 1 harness).
pub fn rowsplit_spmv(a: &Csr, x: &[f32], p: usize) -> Vec<f32> {
    assert_eq!(x.len(), a.k);
    let p = effective_workers(p, a.m);
    let mut y = vec![0.0f32; a.m];
    if a.m == 0 {
        return y;
    }
    let segs = RowSplit::default().partition(a, p);
    let base = SendPtr(y.as_mut_ptr());
    crate::exec::global_pool().broadcast(segs.len(), &|s| {
        let seg = segs[s];
        let ptr = base.0.wrapping_add(seg.row_start);
        // SAFETY: disjoint row ranges (see rowsplit_spmm_into_granular).
        let chunk = unsafe { std::slice::from_raw_parts_mut(ptr, seg.rows()) };
        for i in seg.row_start..seg.row_end {
            let (cols, vals) = a.row(i);
            chunk[i - seg.row_start] = cols
                .iter()
                .zip(vals)
                .map(|(&c, &v)| v * x[c as usize])
                .sum();
        }
    });
    y
}

pub(crate) fn effective_workers(p: usize, work_items: usize) -> usize {
    let avail = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    let p = if p == 0 { avail } else { p };
    p.min(work_items.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::spmm_reference;

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matches_reference() {
        let a = Csr::random(200, 150, 8.0, 301);
        let b = crate::gen::dense_matrix(150, 16, 302);
        for p in [1, 2, 4, 8] {
            assert_close(&rowsplit_spmm(&a, &b, 16, p), &spmm_reference(&a, &b, 16));
        }
    }

    #[test]
    fn both_granularities_agree() {
        let a = Csr::random(100, 100, 3.0, 303);
        let b = crate::gen::dense_matrix(100, 8, 304);
        let w = rowsplit_spmm_granular(&a, &b, 8, 4, Granularity::WarpPerRow);
        let t = rowsplit_spmm_granular(&a, &b, 8, 4, Granularity::ThreadPerRow);
        assert_close(&w, &t);
    }

    #[test]
    fn row_length_33_batch_boundary() {
        // the paper's L-sensitivity case: one extra batch per row
        let a = crate::gen::uniform_rows(64, 33, Some(256), 305);
        let b = crate::gen::dense_matrix(256, 8, 306);
        assert_close(&rowsplit_spmm(&a, &b, 8, 4), &spmm_reference(&a, &b, 8));
    }

    #[test]
    fn wide_dense_widths_cross_tile_boundaries() {
        // n > 64 exercises the column-tiled path: exact multiple, off-by-one
        // around TILE_WIDTH, and a ragged final tile
        let a = Csr::random(80, 90, 7.0, 312);
        for n in [63, 64, 65, 100, 128, 200] {
            let b = crate::gen::dense_matrix(90, n, 313 + n as u64);
            let want = spmm_reference(&a, &b, n);
            assert_close(&rowsplit_spmm(&a, &b, n, 4), &want);
            let t = rowsplit_spmm_granular(&a, &b, n, 4, Granularity::ThreadPerRow);
            assert_close(&t, &want);
        }
    }

    #[test]
    fn into_reuses_buffer_and_overwrites_stale_data() {
        let a = Csr::random(60, 60, 5.0, 314);
        let b = crate::gen::dense_matrix(60, 8, 315);
        let want = spmm_reference(&a, &b, 8);
        let segs = RowSplit::default().partition(&a, 4);
        let mut ctx = ExecCtx::with_global_pool();
        let mut c = vec![f32::NAN; 60 * 8]; // stale garbage must vanish
        for _ in 0..3 {
            rowsplit_spmm_into(&a, &b, 8, &segs, &mut ctx, &mut c);
            assert_close(&c, &want);
        }
    }

    #[test]
    fn empty_and_degenerate() {
        let a = Csr::empty(10, 10);
        let b = crate::gen::dense_matrix(10, 4, 307);
        assert_eq!(rowsplit_spmm(&a, &b, 4, 2), vec![0.0; 40]);
        let a0 = Csr::empty(0, 10);
        assert!(rowsplit_spmm(&a0, &b, 4, 2).is_empty());
    }

    #[test]
    fn spmv_matches() {
        let a = Csr::random(300, 200, 5.0, 308);
        let x = crate::gen::dense_matrix(200, 1, 309);
        let y = rowsplit_spmv(&a, &x, 4);
        let want = crate::spmm::spmv_reference(&a, &x);
        assert_close(&y, &want);
    }

    #[test]
    fn more_workers_than_rows() {
        let a = Csr::random(3, 10, 2.0, 310);
        let b = crate::gen::dense_matrix(10, 4, 311);
        assert_close(&rowsplit_spmm(&a, &b, 4, 64), &spmm_reference(&a, &b, 4));
    }
}
