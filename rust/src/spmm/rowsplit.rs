//! Algorithm I — row-split SpMM executor (paper §4.1).
//!
//! One thread plays one "warp": it owns a contiguous block of rows (the
//! [`RowSplit`] decomposition) and streams each row's nonzeros in
//! `WARP_BATCH`-wide chunks, exactly the paper's "batches of 32"
//! structure.  The per-chunk inner loop over the dense width `n` is the
//! lane dimension — each iteration is the independent, coalesced B-row
//! load that thread `j` of the warp performs — and is written stride-1
//! over both `B` and `C` rows so the compiler vectorizes it (the CPU
//! analogue of coalescing; see DESIGN.md §Hardware-Adaptation).

use crate::formats::Csr;
use crate::loadbalance::{Partitioner, RowSplit};

/// The paper's warp width: nonzeros are processed in batches of 32.
pub const WARP_BATCH: usize = 32;

/// Row-granularity choice (paper §4.1 design decision 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// one thread per row — wins on very short rows (Fig. 4 left)
    ThreadPerRow,
    /// one warp per row — the paper's default
    WarpPerRow,
}

/// Row-split SpMM: `C = A·B` with `p` parallel workers.
///
/// * `b` is `k×n` row-major, result is `m×n` row-major.
/// * `p = 0` → use available parallelism.
pub fn rowsplit_spmm(a: &Csr, b: &[f32], n: usize, p: usize) -> Vec<f32> {
    rowsplit_spmm_granular(a, b, n, p, Granularity::WarpPerRow)
}

/// Row-split with an explicit granularity (exposed for the Fig. 4 bench).
pub fn rowsplit_spmm_granular(
    a: &Csr,
    b: &[f32],
    n: usize,
    p: usize,
    gran: Granularity,
) -> Vec<f32> {
    assert_eq!(b.len(), a.k * n, "B must be k×n row-major");
    let p = effective_workers(p, a.m);
    let mut c = vec![0.0f32; a.m * n];
    if a.m == 0 || n == 0 {
        return c;
    }
    let segs = RowSplit::default().partition(a, p);

    std::thread::scope(|scope| {
        let mut rest: &mut [f32] = &mut c;
        let mut offset = 0usize;
        for seg in &segs {
            let rows = seg.row_end - seg.row_start;
            debug_assert_eq!(seg.row_start * n, offset);
            let (chunk, tail) = rest.split_at_mut(rows * n);
            rest = tail;
            offset += rows * n;
            let seg = *seg;
            scope.spawn(move || {
                for i in seg.row_start..seg.row_end {
                    let out = &mut chunk[(i - seg.row_start) * n..(i - seg.row_start + 1) * n];
                    match gran {
                        Granularity::WarpPerRow => row_kernel_warp(a, b, n, i, out),
                        Granularity::ThreadPerRow => row_kernel_thread(a, b, n, i, out),
                    }
                }
            });
        }
    });
    c
}

/// Warp-per-row inner kernel: nonzeros in WARP_BATCH chunks; within a
/// chunk the B-row loads are independent (the ILP Table 1 counts) and the
/// n-wide FMA is the coalesced lane dimension.
///
/// §Perf: for n ≤ 64 the accumulator lives in a fixed-size stack tile (the
/// CPU analogue of the paper's 64-register accumulator, Table 1) so the
/// compiler keeps it in vector registers across the whole row instead of
/// re-touching the C row per nonzero.
#[inline]
fn row_kernel_warp(a: &Csr, b: &[f32], n: usize, i: usize, out: &mut [f32]) {
    let (cols, vals) = a.row(i);
    if n <= 64 {
        let mut acc = [0.0f32; 64];
        for (&col, &v) in cols.iter().zip(vals) {
            let brow = &b[col as usize * n..col as usize * n + n];
            for (o, &bv) in acc[..n].iter_mut().zip(brow) {
                *o += v * bv;
            }
        }
        out.copy_from_slice(&acc[..n]);
        return;
    }
    let mut pos = 0usize;
    while pos < cols.len() {
        let end = (pos + WARP_BATCH).min(cols.len());
        // One "warp batch": up to 32 independent B-row gathers.
        for t in pos..end {
            let col = cols[t] as usize;
            let v = vals[t];
            let brow = &b[col * n..col * n + n];
            // lane dimension: stride-1 over n → vectorized FMA
            for (o, &bv) in out.iter_mut().zip(brow) {
                *o += v * bv;
            }
        }
        pos = end;
    }
}

/// Thread-per-row kernel: a single serial walk (no batching) — models the
/// alternative granularity that wins for very short rows.
#[inline]
fn row_kernel_thread(a: &Csr, b: &[f32], n: usize, i: usize, out: &mut [f32]) {
    let (cols, vals) = a.row(i);
    for (&col, &v) in cols.iter().zip(vals) {
        let brow = &b[col as usize * n..col as usize * n + n];
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += v * bv;
        }
    }
}

/// Row-split SpMV (n = 1 specialization used by the Fig. 1 harness).
pub fn rowsplit_spmv(a: &Csr, x: &[f32], p: usize) -> Vec<f32> {
    assert_eq!(x.len(), a.k);
    let p = effective_workers(p, a.m);
    let mut y = vec![0.0f32; a.m];
    if a.m == 0 {
        return y;
    }
    let segs = RowSplit::default().partition(a, p);
    std::thread::scope(|scope| {
        let mut rest: &mut [f32] = &mut y;
        for seg in &segs {
            let rows = seg.row_end - seg.row_start;
            let (chunk, tail) = rest.split_at_mut(rows);
            rest = tail;
            let seg = *seg;
            scope.spawn(move || {
                for i in seg.row_start..seg.row_end {
                    let (cols, vals) = a.row(i);
                    chunk[i - seg.row_start] = cols
                        .iter()
                        .zip(vals)
                        .map(|(&c, &v)| v * x[c as usize])
                        .sum();
                }
            });
        }
    });
    y
}

pub(crate) fn effective_workers(p: usize, work_items: usize) -> usize {
    let avail = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    let p = if p == 0 { avail } else { p };
    p.min(work_items.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::spmm_reference;

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matches_reference() {
        let a = Csr::random(200, 150, 8.0, 301);
        let b = crate::gen::dense_matrix(150, 16, 302);
        for p in [1, 2, 4, 8] {
            assert_close(&rowsplit_spmm(&a, &b, 16, p), &spmm_reference(&a, &b, 16));
        }
    }

    #[test]
    fn both_granularities_agree() {
        let a = Csr::random(100, 100, 3.0, 303);
        let b = crate::gen::dense_matrix(100, 8, 304);
        let w = rowsplit_spmm_granular(&a, &b, 8, 4, Granularity::WarpPerRow);
        let t = rowsplit_spmm_granular(&a, &b, 8, 4, Granularity::ThreadPerRow);
        assert_close(&w, &t);
    }

    #[test]
    fn row_length_33_batch_boundary() {
        // the paper's L-sensitivity case: one extra batch per row
        let a = crate::gen::uniform_rows(64, 33, Some(256), 305);
        let b = crate::gen::dense_matrix(256, 8, 306);
        assert_close(&rowsplit_spmm(&a, &b, 8, 4), &spmm_reference(&a, &b, 8));
    }

    #[test]
    fn empty_and_degenerate() {
        let a = Csr::empty(10, 10);
        let b = crate::gen::dense_matrix(10, 4, 307);
        assert_eq!(rowsplit_spmm(&a, &b, 4, 2), vec![0.0; 40]);
        let a0 = Csr::empty(0, 10);
        assert!(rowsplit_spmm(&a0, &b, 4, 2).is_empty());
    }

    #[test]
    fn spmv_matches() {
        let a = Csr::random(300, 200, 5.0, 308);
        let x = crate::gen::dense_matrix(200, 1, 309);
        let y = rowsplit_spmv(&a, &x, 4);
        let want = crate::spmm::spmv_reference(&a, &x);
        assert_close(&y, &want);
    }

    #[test]
    fn more_workers_than_rows() {
        let a = Csr::random(3, 10, 2.0, 310);
        let b = crate::gen::dense_matrix(10, 4, 311);
        assert_close(&rowsplit_spmm(&a, &b, 4, 64), &spmm_reference(&a, &b, 4));
    }
}
