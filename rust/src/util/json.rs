//! Minimal JSON parser + serializer.
//!
//! The offline vendor set has no serde, so this is a small, strict
//! recursive-descent parser covering the JSON subset the AOT manifest
//! uses (objects, arrays, strings, integers/floats, bools, null), plus a
//! `Display`-based serializer (used by `MetricsSnapshot::to_json`) whose
//! output the parser round-trips.  It is not a general-purpose library —
//! but it is fully tested, rejects malformed input, and keeps the
//! manifest as the single source of truth between Python and Rust.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Serializer: compact JSON (no whitespace) that [`Json::parse`]
/// round-trips.  Non-finite numbers have no JSON representation and are
/// emitted as `null`; finite floats use Rust's shortest round-trip
/// `Display`, with a `.0` suffix dropped (integers print as integers).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    f.write_str("null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                f.write_str("[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut std::fmt::Formatter<'_>, s: &str) -> std::fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            '\u{8}' => f.write_str("\\b")?,
            '\u{c}' => f.write_str("\\f")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("short \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|e| e.to_string())?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "format": "hlo-text-v1",
          "artifacts": [
            {"name": "gemm_m1024", "file": "gemm.hlo.txt",
             "args": [{"name": "a", "shape": [1024, 1024], "dtype": "float32"}],
             "out": {"shape": [1024, 64], "dtype": "float32"},
             "meta": {"entry": "gemm", "m": 1024}}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text-v1");
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        let a0 = &arts[0];
        assert_eq!(a0.get("name").unwrap().as_str().unwrap(), "gemm_m1024");
        let shape = a0.get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 1024);
        assert_eq!(a0.get("meta").unwrap().get("m").unwrap().as_usize(), Some(1024));
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "1 2", ""] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn nested_and_unicode() {
        let v = Json::parse(r#"{"a": [[1, 2], {"b": "héllo"}], "c": false}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "héllo"
        );
    }

    #[test]
    fn serializer_round_trips_through_parser() {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str("a\"b\\c\nd\u{1}".into()));
        m.insert("n".into(), Json::Num(12.0));
        m.insert("x".into(), Json::Num(0.125));
        m.insert(
            "arr".into(),
            Json::Arr(vec![Json::Null, Json::Bool(true), Json::Num(-3.0)]),
        );
        m.insert("empty_obj".into(), Json::Obj(BTreeMap::new()));
        m.insert("empty_arr".into(), Json::Arr(vec![]));
        let v = Json::Obj(m);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v, "{text}");
        // integers serialize without a fractional part
        assert!(text.contains("\"n\":12,"), "{text}");
    }

    #[test]
    fn serializer_maps_non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-2").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }
}
