//! Small shared utilities: deterministic RNG, statistics, timing.

pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::XorShift;
pub use stats::{geomean, gflops, mean, percentile};
pub use timer::Timer;
