//! Small shared utilities: deterministic RNG, statistics, timing, and the
//! poison-recovering lock guards the audit pass (R1) enforces.

pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod timer;

pub use rng::XorShift;
pub use stats::{geomean, gflops, mean, percentile};
pub use timer::Timer;
