//! Deterministic xorshift* RNG.
//!
//! Every generator in [`crate::gen`] is seeded, so the 157-matrix suite and
//! all synthetic workloads are bit-reproducible across runs and machines —
//! a requirement for regenerating the paper's tables — without pulling in a
//! heavier dependency.

/// xorshift64* — fast, full-period (2^64−1), passes BigCrush on high bits.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Create a generator; `seed` may be any value (0 is remapped).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift bounded sampling (Lemire); bias < 2^-32 for n < 2^32.
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard-normal-ish f32 via sum of 4 uniforms (Irwin–Hall, cheap and
    /// deterministic; exact normality is irrelevant to the workloads).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        (self.f32() + self.f32() + self.f32() + self.f32() - 2.0) * 1.732_050_8
    }

    /// Pareto-distributed row length with shape `alpha`, min 1, capped.
    pub fn pareto(&mut self, alpha: f64, cap: usize) -> usize {
        let u = (self.f32() as f64).max(1e-9);
        let v = (1.0 / u.powf(1.0 / alpha)) as usize;
        v.clamp(1, cap.max(1))
    }

    /// Sample `count` distinct values in `[0, n)`, ascending (Floyd's).
    pub fn distinct_sorted(&mut self, count: usize, n: usize) -> Vec<u32> {
        let count = count.min(n);
        if count == 0 {
            return Vec::new();
        }
        // For dense draws, a partial Fisher–Yates over a bitmap beats Floyd.
        if count * 4 >= n {
            let mut all: Vec<u32> = (0..n as u32).collect();
            for i in 0..count {
                let j = i + self.below(n - i);
                all.swap(i, j);
            }
            let mut out = all[..count].to_vec();
            out.sort_unstable();
            out
        } else {
            let mut set = std::collections::BTreeSet::new();
            for j in (n - count)..n {
                let t = self.below(j + 1);
                if !set.insert(t as u32) {
                    set.insert(j as u32);
                }
            }
            set.into_iter().collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShift::new(9);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn distinct_sorted_is_distinct_and_sorted() {
        let mut r = XorShift::new(11);
        for &(c, n) in &[(5usize, 100usize), (50, 60), (0, 10), (10, 10), (99, 100)] {
            let v = r.distinct_sorted(c, n);
            assert_eq!(v.len(), c.min(n));
            for w in v.windows(2) {
                assert!(w[0] < w[1], "not strictly ascending: {v:?}");
            }
            assert!(v.iter().all(|&x| (x as usize) < n));
        }
    }

    #[test]
    fn pareto_bounds() {
        let mut r = XorShift::new(13);
        for _ in 0..1000 {
            let v = r.pareto(1.5, 40);
            assert!((1..=40).contains(&v));
        }
    }

    #[test]
    fn zero_seed_remapped() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
