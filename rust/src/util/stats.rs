//! Statistics used throughout the benchmark harness.
//!
//! The paper reports *geomean* speedups (31.7 % over csrmm2) and *peak*
//! speedups (4.1×); these helpers compute them the same way.

/// Geometric mean of positive values. Returns 1.0 for an empty slice.
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 1.0;
    }
    let log_sum: f64 = values
        .iter()
        .map(|&v| {
            debug_assert!(v > 0.0, "geomean needs positive values, got {v}");
            v.max(f64::MIN_POSITIVE).ln()
        })
        .sum();
    (log_sum / values.len() as f64).exp()
}

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// p-th percentile (0–100) by nearest-rank on a copy.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// GFlop/s for an SpMM-style op: 2·nnz·n flops in `seconds`.
pub fn gflops(nnz: usize, n: usize, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    (2.0 * nnz as f64 * n as f64) / seconds / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 1.0);
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn gflops_basics() {
        // 2 * 1e9 * 1 flops in 2 s = 1 GFlop/s
        assert!((gflops(1_000_000_000, 1, 2.0) - 1.0).abs() < 1e-9);
        assert_eq!(gflops(10, 10, 0.0), 0.0);
    }
}
