//! Poison-recovering lock guards — THE way this crate takes a mutex.
//!
//! A `Mutex` poisons when a holder panics, and every later
//! `.lock().unwrap()` on it panics too: one panicking worker becomes a
//! cascade across every sibling that shares the lock (the failure mode
//! PR 4 fixed in the work queue).  Every critical section in this crate
//! is a short push/pop/swap that leaves the data consistent even if the
//! holder unwinds mid-section, so recovery is always safe — and the audit
//! pass (rule R1, `tools/audit`) bans bare `.lock().unwrap()` /
//! `.lock().expect(` in production code in favour of these guards.
//!
//! Panic boundaries stay where they were: callers that want to *surface*
//! a panic still do so via `catch_unwind` at the request boundary; these
//! helpers only keep the shared state reachable afterwards.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Acquire `lock`, shrugging off poisoning: a panicking former holder
/// left the data in a consistent state (every critical section in this
/// crate is a short push/pop/swap), so the poison flag carries no
/// information worth dying for.
pub fn recover<T>(lock: &Mutex<T>) -> MutexGuard<'_, T> {
    lock.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison-recovery contract as
/// [`recover`]: a sibling's panic while we were parked must not take this
/// waiter down with it.
pub fn recover_wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let mc = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = mc.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*recover(&m), 7);
        *recover(&m) = 9;
        assert_eq!(*recover(&m), 9);
    }

    #[test]
    fn recover_wait_wakes_through_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pc = Arc::clone(&pair);
        // poison the mutex first so the waiter must recover on wake
        let pp = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _g = pp.0.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(pair.0.is_poisoned());
        let waker = std::thread::spawn(move || {
            *recover(&pc.0) = true;
            pc.1.notify_all();
        });
        let mut done = recover(&pair.0);
        while !*done {
            done = recover_wait(&pair.1, done);
        }
        waker.join().unwrap();
    }
}
