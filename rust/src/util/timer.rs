//! Wall-clock timing helper with warmup + repetition, used by the bench
//! harness (criterion handles the statistical benches; this is for the
//! figure-regeneration binaries where we want one number per cell).

use std::time::Instant;

/// Run `f` `warmup` times untimed, then `reps` times timed; report the
/// *minimum* wall-clock seconds (the standard noise-robust estimator).
pub struct Timer {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for Timer {
    fn default() -> Self {
        Self { warmup: 1, reps: 3 }
    }
}

impl Timer {
    pub fn new(warmup: usize, reps: usize) -> Self {
        Self {
            warmup,
            reps: reps.max(1),
        }
    }

    /// Time `f`, returning min seconds across reps.
    pub fn time<F: FnMut()>(&self, mut f: F) -> f64 {
        for _ in 0..self.warmup {
            f();
        }
        let mut best = f64::INFINITY;
        for _ in 0..self.reps {
            let t0 = Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_returns_positive() {
        let t = Timer::default();
        let mut acc = 0u64;
        let secs = t.time(|| {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
        });
        assert!(secs >= 0.0);
        assert!(secs.is_finite());
    }
}
