//! Chaos property suite (`--features faults`): under deterministic
//! injected panics, delays, and queue squeeze — on top of tight
//! deadlines, cancellations, and dropped handles — every submission
//! reaches exactly one terminal outcome, no worker wedges (shutdown
//! drains and joins), and every surviving result is bitwise-identical
//! to a fault-free baseline.
//!
//! The fault plan is process-global, so this file holds a single test:
//! a second PLAN-touching test would race it under the parallel test
//! runner.

#![cfg(feature = "faults")]

use std::sync::Arc;
use std::time::Duration;

use merge_spmm::coordinator::faults::{self, FaultPlan};
use merge_spmm::coordinator::{Deadline, EngineConfig, Server, ServerConfig};
use merge_spmm::formats::Csr;
use merge_spmm::gen;

fn cpu_cfg() -> EngineConfig {
    EngineConfig {
        artifacts_dir: None,
        threshold: 9.35,
        cpu_workers: 2,
        ..Default::default()
    }
}

/// Clears the global fault plan even when an assert unwinds mid-test, so
/// a failure here cannot poison unit tests running in the same process.
struct ClearGuard;
impl Drop for ClearGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

#[test]
fn chaos_every_request_reaches_exactly_one_terminal_outcome() {
    // d ≈ 4 keeps every matrix outside the A/B-probe band: plans are
    // deterministic, so fused and solo execution are bitwise-identical
    // and the baseline below is a valid reference for survivors.
    let mats: Vec<(Arc<Csr>, Arc<Vec<f32>>)> = (0..4)
        .map(|i| {
            let m = 200 + i * 40;
            let seed = 9000 + i as u64 * 10;
            (
                Arc::new(Csr::random(m, m, 4.0, seed)),
                Arc::new(gen::dense_matrix(m, 8, seed + 1)),
            )
        })
        .collect();

    // fault-free baseline, batching off: one solo pass per matrix
    let clean = Server::start(
        cpu_cfg(),
        ServerConfig { max_batch: 1, ..Default::default() },
    )
    .unwrap();
    let baseline: Vec<Vec<f32>> = mats
        .iter()
        .map(|(a, b)| {
            clean
                .submit_blocking(Arc::clone(a), Arc::clone(b), 8)
                .unwrap()
                .c
                .into_vec()
        })
        .collect();
    clean.shutdown();

    let _guard = ClearGuard;
    faults::install(FaultPlan {
        seed: 0xC4A05,
        panic_one_in: 5,
        delay_one_in: 3,
        delay: Duration::from_millis(2),
        squeeze_queue_to: 4,
        ..FaultPlan::default()
    });

    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 8,
            ..Default::default()
        },
    )
    .unwrap();

    const N: usize = 48;
    let mut kept = Vec::new();
    let mut dropped = 0u64;
    for i in 0..N {
        let (a, b) = &mats[i % mats.len()];
        let deadline = if i == 7 {
            Deadline::within(Duration::ZERO) // guaranteed dead on arrival
        } else {
            match i % 3 {
                0 => Deadline::none(),
                1 => Deadline::within(Duration::from_millis(2)), // tight
                _ => Deadline::within(Duration::from_secs(30)),  // generous
            }
        };
        let h = server
            .submit_with(Arc::clone(a), Arc::clone(b), 8, deadline)
            .unwrap();
        if i % 6 == 5 {
            h.cancel();
        }
        if i % 8 == 3 {
            drop(h); // Drop cancels: its terminal outcome lands in the counters
            dropped += 1;
        } else {
            kept.push((i, h));
        }
    }

    let (mut ok, mut shed, mut errs) = (0u64, 0u64, 0u64);
    for (i, h) in &kept {
        match h.recv().expect("every kept handle gets exactly one terminal outcome") {
            Ok(r) => {
                let want = &baseline[i % mats.len()];
                assert_eq!(r.c.len(), want.len(), "request {i}: wrong output shape");
                assert!(
                    r.c.iter().zip(want).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "request {i}: survivor must be bitwise-identical to the fault-free baseline"
                );
                ok += 1;
            }
            Err(e) => {
                let msg = e.to_string();
                if msg.starts_with("shed (") {
                    shed += 1;
                } else {
                    assert!(msg.contains("panicked"), "request {i}: unexpected error: {msg}");
                    errs += 1;
                }
            }
        }
        assert!(h.try_recv().is_err(), "request {i} got a second terminal message");
    }
    let accounted_via_handles = ok + shed + errs;
    assert_eq!(accounted_via_handles, kept.len() as u64);
    drop(kept);

    // no worker wedges: shutdown drains the queues and joins every thread
    let snap = server.shutdown();

    // conservation: every one of the 48 submissions — including dropped
    // handles, whose replies nobody read — lands in exactly one terminal
    // counter.
    let terminal =
        snap.completed + snap.errors + snap.shed_deadline + snap.shed_codel + snap.cancelled;
    assert_eq!(terminal, N as u64, "terminal outcomes must conserve submissions: {snap}");
    // a dropped handle may have slipped into execution before its
    // cancellation was observed, so completed/errors can each exceed the
    // handle-side tallies — but only by at most the dropped count.
    assert!(snap.completed >= ok && snap.completed - ok <= dropped, "{snap}");
    assert!(snap.errors >= errs && snap.errors - errs <= dropped, "{snap}");
    assert!(snap.cancelled >= 1, "explicit cancels must register: {snap}");
    assert!(snap.shed_deadline >= 1, "the dead-on-arrival request must shed: {snap}");
}
