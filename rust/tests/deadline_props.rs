//! End-to-end admission-control properties: expired deadlines and
//! cancelled handles are shed with exactly one terminal error, dropped
//! handles count as cancellations, the server-wide default deadline
//! stamps plain `submit`, and an overload mix conserves requests across
//! the terminal counters.  CPU-only so it runs on a fresh checkout.

use std::sync::Arc;
use std::time::Duration;

use merge_spmm::coordinator::{Deadline, EngineConfig, Server, ServerConfig};
use merge_spmm::formats::Csr;
use merge_spmm::gen;

fn cpu_cfg() -> EngineConfig {
    EngineConfig {
        artifacts_dir: None,
        threshold: 9.35,
        cpu_workers: 2,
        ..Default::default()
    }
}

/// d ≈ 4 keeps every matrix outside the A/B-probe band so plans (and
/// therefore timing) stay deterministic across servers.
fn fixture(seed: u64) -> (Arc<Csr>, Arc<Vec<f32>>) {
    let a = Arc::new(Csr::random(300, 300, 4.0, seed));
    let b = Arc::new(gen::dense_matrix(300, 8, seed + 1));
    (a, b)
}

#[test]
fn expired_deadline_is_shed_with_one_terminal_error() {
    let server = Server::start(cpu_cfg(), ServerConfig::default()).unwrap();
    let (a, b) = fixture(2101);

    let h = server
        .submit_with(Arc::clone(&a), Arc::clone(&b), 8, Deadline::within(Duration::ZERO))
        .unwrap();
    let err = h.recv().expect("a shed request still gets a terminal reply").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("shed (deadline-expired)"), "{msg}");
    assert!(msg.contains(&format!("request {}", h.id())), "{msg}");
    assert!(h.try_recv().is_err(), "a request must get exactly one terminal message");

    // the server keeps serving fresh requests after a shed
    let r = server.submit_blocking(Arc::clone(&a), Arc::clone(&b), 8).unwrap();
    assert_eq!(r.c.len(), 300 * 8);

    let snap = server.shutdown();
    assert_eq!(snap.shed_deadline, 1);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.cancelled, 0);
    assert_eq!(snap.errors, 0);
}

#[test]
fn cancelled_handle_is_shed_before_execution() {
    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        },
    )
    .unwrap();
    let (a, b) = fixture(2111);

    let victim = server.submit(Arc::clone(&a), Arc::clone(&b), 8).unwrap();
    victim.cancel();
    let rest: Vec<_> = (0..3)
        .map(|_| server.submit(Arc::clone(&a), Arc::clone(&b), 8).unwrap())
        .collect();

    let err = victim.recv().expect("cancelled request gets a terminal reply").unwrap_err();
    assert!(err.to_string().contains("shed (cancelled)"), "{err}");
    for h in rest {
        h.recv().unwrap().unwrap();
    }

    let snap = server.shutdown();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.errors, 0);
}

#[test]
fn dropped_handle_counts_as_cancelled() {
    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        },
    )
    .unwrap();
    let (a, b) = fixture(2121);

    let victim = server.submit(Arc::clone(&a), Arc::clone(&b), 8).unwrap();
    drop(victim); // no reply received yet → Drop cancels the token
    let rest: Vec<_> = (0..3)
        .map(|_| server.submit(Arc::clone(&a), Arc::clone(&b), 8).unwrap())
        .collect();
    for h in rest {
        h.recv().unwrap().unwrap();
    }

    let snap = server.shutdown();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.errors, 0);
}

#[test]
fn server_default_deadline_applies_to_plain_submit() {
    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            deadline: Some(Duration::from_nanos(1)),
            ..Default::default()
        },
    )
    .unwrap();
    let (a, b) = fixture(2131);

    // plain submit inherits the (already-expired) server default …
    let h = server.submit(Arc::clone(&a), Arc::clone(&b), 8).unwrap();
    let err = h.recv().unwrap().unwrap_err();
    assert!(err.to_string().contains("shed (deadline-expired)"), "{err}");

    // … while an explicit Deadline::none() overrides it
    let h = server
        .submit_with(Arc::clone(&a), Arc::clone(&b), 8, Deadline::none())
        .unwrap();
    h.recv().unwrap().expect("explicit no-deadline request must run");

    let snap = server.shutdown();
    assert_eq!(snap.shed_deadline, 1);
    assert_eq!(snap.completed, 1);
}

#[test]
fn overload_mix_yields_exactly_one_terminal_outcome_per_request() {
    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let a = Arc::new(Csr::random(800, 800, 4.0, 2141));
    let b = Arc::new(gen::dense_matrix(800, 32, 2142));

    let handles: Vec<_> = (0..16)
        .map(|i| {
            let d = if i % 2 == 0 {
                Deadline::none()
            } else {
                Deadline::within(Duration::ZERO)
            };
            server
                .submit_with(Arc::clone(&a), Arc::clone(&b), 32, d)
                .unwrap()
        })
        .collect();

    let (mut ok, mut shed) = (0u64, 0u64);
    for h in &handles {
        match h.recv().expect("every request gets exactly one terminal outcome") {
            Ok(r) => {
                assert_eq!(r.c.len(), 800 * 32);
                ok += 1;
            }
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.starts_with("shed ("), "unexpected error shape: {msg}");
                shed += 1;
            }
        }
        assert!(h.try_recv().is_err(), "second message for one request");
    }
    assert_eq!(ok, 8, "no-deadline requests all complete");
    assert_eq!(shed, 8, "zero-budget requests all shed");

    let snap = server.shutdown();
    assert_eq!(snap.completed, 8);
    assert_eq!(
        snap.completed + snap.errors + snap.shed_deadline + snap.shed_codel + snap.cancelled,
        16,
        "terminal outcomes must conserve submissions: {snap}"
    );
}
