//! Coordinator integration: server under concurrent load, batching
//! invariants, metrics consistency.  CPU-only (no artifacts needed) so it
//! runs on a fresh checkout.

use std::sync::Arc;
use std::time::Duration;

use merge_spmm::coordinator::{EngineConfig, Server, ServerConfig};
use merge_spmm::formats::Csr;
use merge_spmm::gen;
use merge_spmm::spmm;
use merge_spmm::util::XorShift;

fn cpu_cfg() -> EngineConfig {
    EngineConfig {
        artifacts_dir: None,
        threshold: 9.35,
        cpu_workers: 2,
        ..Default::default()
    }
}

#[test]
fn concurrent_load_no_drops() {
    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            workers: 4,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 64,
            ..Default::default()
        },
    )
    .unwrap();
    let mut rng = XorShift::new(0xD41);
    let mats: Vec<Arc<Csr>> = (0..6)
        .map(|i| Arc::new(Csr::random(200 + i * 50, 300, 2.0 + i as f64 * 4.0, 3000 + i as u64)))
        .collect();
    let bs: Vec<Arc<Vec<f32>>> = (0..1).map(|_| Arc::new(gen::dense_matrix(300, 8, 3100))).collect();

    let total = 300usize;
    let mut handles = Vec::new();
    let mut expect = Vec::new();
    for _ in 0..total {
        let mi = rng.below(mats.len());
        let a = Arc::clone(&mats[mi]);
        let b = Arc::clone(&bs[0]);
        expect.push(mi);
        handles.push(server.submit(a, b, 8).unwrap());
    }
    let mut ok = 0;
    for (h, &mi) in handles.iter().zip(&expect) {
        let r = h.recv().unwrap().unwrap();
        let want = spmm::spmm_reference(&mats[mi], &bs[0], 8);
        for (x, y) in r.c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()));
        }
        ok += 1;
    }
    assert_eq!(ok, total);
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, total);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.rowsplit + snap.merge, total as u64);
    assert!(snap.p50_s > 0.0);
}

#[test]
fn submissions_during_shutdown_dont_hang() {
    let server = Server::start(cpu_cfg(), ServerConfig::default()).unwrap();
    let a = Arc::new(Csr::random(50, 50, 3.0, 3200));
    let b = Arc::new(gen::dense_matrix(50, 4, 3201));
    let h = server.submit(Arc::clone(&a), Arc::clone(&b), 4).unwrap();
    let _ = h.recv();
    let snap = server.shutdown();
    assert!(snap.completed >= 1);
}

#[test]
fn throughput_scales_with_workers() {
    // Not a strict perf assertion (CI noise); just checks more workers
    // don't serialize: 4 workers must not be slower than 1 by 2×.
    let run = |workers: usize| -> f64 {
        let server = Server::start(
            cpu_cfg(),
            ServerConfig {
                workers,
                max_batch: 1,
                max_wait: Duration::from_micros(100),
                queue_capacity: 512,
                ..Default::default()
            },
        )
        .unwrap();
        let a = Arc::new(gen::uniform_rows(600, 24, Some(600), 3300));
        let b = Arc::new(gen::dense_matrix(600, 32, 3301));
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..60)
            .map(|_| server.submit(Arc::clone(&a), Arc::clone(&b), 32).unwrap())
            .collect();
        for h in handles {
            let _ = h.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        server.shutdown();
        dt
    };
    let t1 = run(1);
    let t4 = run(4);
    assert!(
        t4 < t1 * 2.0,
        "4 workers ({t4:.3}s) must not be 2x slower than 1 ({t1:.3}s)"
    );
}
