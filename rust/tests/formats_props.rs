//! Property tests for format conversions: every format round-trips
//! through CSR losslessly, and all formats describe the same dense matrix.

use merge_spmm::formats::{mm, Coo, Csc, Csr, Dcsr, Ell, SellP};
use merge_spmm::util::XorShift;

fn arb_csr(rng: &mut XorShift) -> Csr {
    let m = rng.below(70);
    let k = 1 + rng.below(70);
    let mut row_ptr = vec![0usize];
    let mut col_idx = Vec::new();
    for _ in 0..m {
        let len = rng.below(k.min(30) + 1);
        col_idx.extend(rng.distinct_sorted(len, k));
        row_ptr.push(col_idx.len());
    }
    let vals = (0..col_idx.len()).map(|_| rng.normal()).collect();
    Csr::new(m, k, row_ptr, col_idx, vals).unwrap()
}

#[test]
fn prop_all_formats_roundtrip() {
    let mut rng = XorShift::new(0xC31);
    for case in 0..200 {
        let a = arb_csr(&mut rng);
        assert_eq!(Coo::from_csr(&a).to_csr().unwrap(), a, "coo case {case}");
        assert_eq!(Csc::from_csr(&a).to_csr(), a, "csc case {case}");
        assert_eq!(Dcsr::from_csr(&a).to_csr(), a, "dcsr case {case}");
        let pad = 1 + rng.below(8);
        assert_eq!(Ell::from_csr(&a, pad).to_csr(), a, "ell case {case}");
        let h = 1 + rng.below(16);
        assert_eq!(SellP::from_csr(&a, h, pad).to_csr(), a, "sellp case {case}");
    }
}

#[test]
fn prop_mm_roundtrip_preserves_dense() {
    let mut rng = XorShift::new(0xC32);
    for case in 0..50 {
        let a = arb_csr(&mut rng);
        if a.m == 0 {
            continue;
        }
        let mut buf = Vec::new();
        mm::write_mm(&a, &mut buf).unwrap();
        let b = mm::read_mm(&buf[..]).unwrap();
        let (da, db) = (a.to_dense(), b.to_dense());
        for (i, (x, y)) in da.iter().zip(&db).enumerate() {
            assert!((x - y).abs() < 1e-4, "case {case} idx {i}");
        }
    }
}

#[test]
fn prop_heavy_light_split_partitions() {
    let mut rng = XorShift::new(0xC33);
    for case in 0..100 {
        let a = arb_csr(&mut rng);
        let threshold = 1 + rng.below(20);
        let (heavy, light) = Dcsr::split_heavy_light(&a, threshold);
        assert_eq!(heavy.nnz() + light.nnz(), a.nnz(), "case {case}");
        // light rows strictly below threshold
        let lc = light.to_csr();
        for i in 0..lc.m {
            assert!(lc.row_len(i) < threshold || lc.row_len(i) == 0);
        }
    }
}

#[test]
fn prop_padding_overhead_at_least_one() {
    let mut rng = XorShift::new(0xC34);
    for _ in 0..100 {
        let a = arb_csr(&mut rng);
        if a.nnz() == 0 {
            continue;
        }
        assert!(Ell::from_csr(&a, 4).padding_overhead() >= 1.0);
        assert!(SellP::from_csr(&a, 8, 4).padding_overhead() >= 1.0);
        // SELL-P never pads more than ELL at equal alignment
        let e = Ell::from_csr(&a, 4).padding_overhead();
        let s = SellP::from_csr(&a, 8, 4).padding_overhead();
        assert!(s <= e + 1e-9, "sellp {s} > ell {e}");
    }
}
