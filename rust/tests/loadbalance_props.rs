//! Property tests for the load-balancing layer (no proptest in the
//! offline vendor set — properties are checked over seeded random case
//! sweeps, 200+ cases each, which is the same contract: any failing case
//! prints its seed for reproduction).

use merge_spmm::formats::Csr;
use merge_spmm::loadbalance::{
    mergepath::merge_coord, validate_segments, MergePath, NonzeroSplit, Partitioner, RowSplit,
};
use merge_spmm::util::XorShift;

/// Random CSR with arbitrary (often pathological) row-length profiles.
fn arb_csr(rng: &mut XorShift) -> Csr {
    let m = 1 + rng.below(60);
    let k = 1 + rng.below(60);
    let mut row_ptr = vec![0usize];
    let mut col_idx = Vec::new();
    for _ in 0..m {
        let style = rng.below(5);
        let len = match style {
            0 => 0,                       // empty
            1 => 1 + rng.below(3),        // short
            2 => rng.below(k.min(40)),    // medium
            3 => k.min(33),               // the 33-boundary case
            _ => k.min(1 + rng.below(k)), // anything
        };
        let cols = rng.distinct_sorted(len, k);
        col_idx.extend(cols);
        row_ptr.push(col_idx.len());
    }
    let nnz = col_idx.len();
    let vals = (0..nnz).map(|i| (i % 7) as f32 - 3.0).collect();
    Csr::new(m, k, row_ptr, col_idx, vals).unwrap()
}

#[test]
fn prop_all_partitioners_tile_exactly() {
    let mut rng = XorShift::new(0xA11);
    for case in 0..250 {
        let csr = arb_csr(&mut rng);
        let p = 1 + rng.below(40);
        for part in [
            &RowSplit::default() as &dyn Partitioner,
            &NonzeroSplit,
            &MergePath,
        ] {
            let segs = part.partition(&csr, p);
            if csr.m == 0 {
                continue;
            }
            validate_segments(&csr, &segs).unwrap_or_else(|e| {
                panic!("case {case} {} p={p}: {e}", part.name());
            });
        }
    }
}

#[test]
fn prop_nzsplit_equal_quota() {
    let mut rng = XorShift::new(0xA12);
    for _ in 0..250 {
        let csr = arb_csr(&mut rng);
        let p = 1 + rng.below(20);
        let nnz = csr.nnz();
        if nnz == 0 {
            continue;
        }
        let per = nnz.div_ceil(p);
        let segs = NonzeroSplit.partition(&csr, p);
        for s in &segs[..segs.len() - 1] {
            assert_eq!(s.nnz(), per);
        }
        assert!(segs.last().unwrap().nnz() <= per);
    }
}

#[test]
fn prop_mergepath_diagonal_monotone() {
    let mut rng = XorShift::new(0xA13);
    for _ in 0..100 {
        let csr = arb_csr(&mut rng);
        let total = csr.m + csr.nnz();
        let (mut pi, mut pj) = (0usize, 0usize);
        for d in 0..=total {
            let (i, j) = merge_coord(&csr, d);
            assert_eq!(i + j, d, "coordinate must sit on the diagonal");
            assert!(i >= pi && j >= pj, "path must be monotone");
            assert!(i <= csr.m && j <= csr.nnz());
            // merge invariant: consumed row-ends all precede next nonzero
            if i > 0 {
                assert!(csr.row_ptr[i] <= j, "d={d}: row-end {i} consumed early");
            }
            (pi, pj) = (i, j);
        }
        let (i, j) = merge_coord(&csr, total);
        assert_eq!((i, j), (csr.m, csr.nnz()));
    }
}

#[test]
fn prop_mergepath_work_within_quantum() {
    let mut rng = XorShift::new(0xA14);
    for _ in 0..200 {
        let csr = arb_csr(&mut rng);
        let p = 1 + rng.below(16);
        let total = csr.m + csr.nnz();
        if total == 0 {
            continue;
        }
        let per = total.div_ceil(p);
        for s in MergePath.partition(&csr, p) {
            // each segment's diagonal span (rows fully consumed + nonzeros)
            // is at most the quantum
            assert!(s.nnz() <= per, "nnz {} > quantum {per}", s.nnz());
        }
    }
}

#[test]
fn prop_rowsplit_never_splits_rows() {
    let mut rng = XorShift::new(0xA15);
    for _ in 0..200 {
        let csr = arb_csr(&mut rng);
        let p = 1 + rng.below(20);
        for s in RowSplit::default().partition(&csr, p) {
            assert_eq!(s.nz_start, csr.row_ptr[s.row_start]);
            assert_eq!(s.nz_end, csr.row_ptr[s.row_end]);
        }
    }
}
