//! Observability properties: the metrics sink under concurrent hammering
//! (totals conserved, f64-bits gauges never torn, journal entries never
//! half-written) and golden export coverage — every `MetricsSnapshot`
//! field must appear in both `to_json()` and `to_prometheus()`, so a new
//! metric cannot silently miss an exporter.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use merge_spmm::coordinator::metrics::{RECENT_JOURNAL_CAP, SLOW_JOURNAL_CAP};
use merge_spmm::coordinator::{Metrics, MetricsSnapshot, Stage, StageBreakdown, TracePath};
use merge_spmm::plan::CacheStats;
use merge_spmm::util::json::Json;

/// A synthetic breakdown whose five stage durations all equal `d` and
/// whose total is exactly `5 d`, with the path index encoded in the id's
/// high bits — a reader can re-derive every field from `id` alone, so any
/// torn journal write is detectable.
fn breakdown(id: u64, path: TracePath, d: f64) -> StageBreakdown {
    let now = Instant::now();
    StageBreakdown {
        id,
        path,
        queue_s: d,
        plan_s: d,
        pack_s: d,
        exec_s: d,
        gather_s: d,
        total_s: 5.0 * d,
        admitted: now,
        plan_span: Some((now, now)),
        pack_span: Some((now, now)),
        exec_span: Some((now, now)),
        gather_span: Some((now, now)),
        shed: None,
    }
}

/// The id-derived duration the writer used (bit-exact: both sides compute
/// the same f64 expression).
fn dur_for(id: u64) -> f64 {
    1e-6 * ((id % 97) + 1) as f64
}

/// N writer threads hammer `record_trace` / `record_fused` (one path
/// each) while gauge writers flip the f64-bits gauges between two exact
/// values and a reader snapshots continuously.  Every snapshot must be
/// self-consistent: path totals only grow, p50 ≤ p99 within one copy,
/// gauges are one of the written values (never a torn bit hybrid), and
/// every journal entry satisfies its id-derived invariants.
#[test]
fn prop_concurrent_recording_conserves_totals_and_never_tears() {
    const PER_THREAD: u64 = 2000;
    let metrics = Arc::new(Metrics::new());
    // 1 µs — sub-µs would truncate to 0 in the µs-integer store and
    // disable the ring; every synthetic total here is ≥ 5 µs
    metrics.set_slow_threshold_s(1e-6);
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        let writers: Vec<_> = TracePath::ALL
            .into_iter()
            .enumerate()
            .map(|(t, path)| {
                let m = Arc::clone(&metrics);
                s.spawn(move || {
                    for i in 0..PER_THREAD {
                        let id = ((t as u64) << 32) | i;
                        m.record_trace(&breakdown(id, path, dur_for(id)));
                        if i % 64 == 0 {
                            m.record_fused(4, 32);
                        }
                    }
                })
            })
            .collect();

        let gauge_writer = {
            let m = Arc::clone(&metrics);
            let st = Arc::clone(&stop);
            s.spawn(move || {
                let cache = CacheStats { hits: 1, misses: 2, evictions: 0, len: 3 };
                let mut flip = false;
                while !st.load(Ordering::Relaxed) {
                    m.sync_plan_gauges(&cache, if flip { 1.25 } else { 2.5 });
                    m.sync_shard_gauges(4, if flip { 1.0 } else { 2.0 });
                    flip = !flip;
                }
            })
        };

        let reader = {
            let m = Arc::clone(&metrics);
            let st = Arc::clone(&stop);
            s.spawn(move || {
                let mut last_total = 0u64;
                let mut snaps = 0u64;
                while !st.load(Ordering::Relaxed) {
                    let snap = m.snapshot();
                    let total: u64 = snap.per_path.iter().map(|p| p.count).sum();
                    assert!(total >= last_total, "path totals went backwards");
                    last_total = total;
                    // both percentiles derive from ONE histogram copy, so
                    // they can never invert within a snapshot
                    for p in snap.per_path.iter().chain(&snap.per_stage) {
                        assert!(p.p50_s <= p.p99_s + 1e-12, "p50 > p99 in one snapshot");
                    }
                    // f64 gauges are stored as whole bit patterns: any read
                    // sees a written value (or the constructor default),
                    // never a torn hybrid
                    assert!(
                        [1.25, 2.5, merge_spmm::spmm::DEFAULT_THRESHOLD]
                            .contains(&snap.tuner_threshold),
                        "torn tuner_threshold gauge: {}",
                        snap.tuner_threshold
                    );
                    assert!(
                        [1.0, 2.0].contains(&snap.shard_imbalance_last),
                        "torn shard_imbalance gauge: {}",
                        snap.shard_imbalance_last
                    );
                    // journal entries are whole-struct writes under the
                    // mutex: the id-derived identities must hold bit-exactly
                    for e in snap.slow_requests.iter().chain(&snap.recent_requests) {
                        let d = dur_for(e.id);
                        assert_eq!(e.queue_s.to_bits(), d.to_bits(), "torn journal entry");
                        assert_eq!(e.total_s.to_bits(), (5.0 * d).to_bits(), "torn journal entry");
                        assert_eq!(e.path.index() as u64, e.id >> 32, "entry path/id mismatch");
                    }
                    snaps += 1;
                }
                snaps
            })
        };

        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        gauge_writer.join().unwrap();
        assert!(reader.join().unwrap() > 0, "reader never snapshotted");
    });

    // conservation: every recorded trace landed in exactly one path bucket
    // and one bucket of every stage histogram
    let snap = metrics.snapshot();
    for p in TracePath::ALL {
        assert_eq!(snap.per_path[p.index()].count, PER_THREAD, "path {} count", p.name());
    }
    for st in Stage::ALL {
        assert_eq!(
            snap.per_stage[st.index()].count,
            5 * PER_THREAD,
            "stage {} count",
            st.name()
        );
    }
    // fused counters: 5 threads × ⌈2000/64⌉ batches of 4 riders, width 32
    assert_eq!(snap.fused_batches, 5 * 32);
    assert_eq!(snap.fused_requests, 5 * 32 * 4);
    assert_eq!(snap.fused_width_mean, 32.0);
    // rings at capacity, never beyond
    assert_eq!(snap.slow_requests.len(), SLOW_JOURNAL_CAP);
    assert_eq!(snap.recent_requests.len(), RECENT_JOURNAL_CAP);
}

/// The mean's denominator is the histogram's own total — not `completed`,
/// which counts different events (regression test for the old mismatch
/// where a request could complete without recording a latency, skewing
/// the mean toward zero).
#[test]
fn mean_latency_uses_histogram_total_as_denominator() {
    let m = Metrics::new();
    m.completed.store(100, Ordering::Relaxed); // unrelated event count
    for _ in 0..4 {
        m.record_latency(0.01);
    }
    let snap = m.snapshot();
    assert_eq!(snap.completed, 100);
    assert_eq!(snap.per_path[TracePath::Solo.index()].count, 4);
    // sum is tracked in integer µs: 4 × 10000µs / 4 = 0.01s exactly
    assert!(
        (snap.mean_latency_s - 0.01).abs() < 1e-9,
        "mean must be sum/total over the histogram, got {}",
        snap.mean_latency_s
    );
    // interpolated percentile lands inside the containing bucket
    assert!(snap.p50_s >= 3e-3 && snap.p50_s <= 3e-2, "p50 {} outside bucket", snap.p50_s);
}

/// A metrics sink with every field exercised: all five paths traced, a
/// fused pass, plan/shard gauges synced, and a slow threshold low enough
/// that every trace journals.
fn populated() -> Metrics {
    let m = Metrics::new();
    m.set_slow_threshold_s(1e-6); // 1 µs: every 100 µs+ synthetic trace journals
    for (i, path) in TracePath::ALL.into_iter().enumerate() {
        m.record_trace(&breakdown(i as u64, path, 1e-4 * (i + 1) as f64));
    }
    m.record_fused(4, 32);
    m.sync_plan_gauges(&CacheStats { hits: 3, misses: 2, evictions: 1, len: 2 }, 9.35);
    m.sync_shard_gauges(4, 1.5);
    m
}

/// `to_json()` must parse with the crate's own parser and its top-level
/// key set must equal `MetricsSnapshot::FIELDS` exactly — both directions,
/// so a new snapshot field without an export (or a stale export) fails.
#[test]
fn golden_json_export_covers_every_snapshot_field() {
    let snap = populated().snapshot();
    let parsed = Json::parse(&snap.to_json()).expect("to_json must be parseable");
    let Json::Obj(map) = &parsed else { panic!("to_json top level must be an object") };
    let got: BTreeSet<&str> = map.keys().map(String::as_str).collect();
    let want: BTreeSet<&str> = MetricsSnapshot::FIELDS.iter().copied().collect();
    assert_eq!(got, want, "to_json keys must match MetricsSnapshot::FIELDS exactly");

    // nested digests are keyed by path/stage name and carry the full shape
    for p in TracePath::ALL {
        let digest = parsed
            .get("per_path")
            .and_then(|v| v.get(p.name()))
            .unwrap_or_else(|| panic!("per_path missing {}", p.name()));
        for k in ["count", "mean_s", "p50_s", "p99_s", "buckets", "sum_us"] {
            assert!(digest.get(k).is_some(), "per_path.{} missing {k}", p.name());
        }
        assert_eq!(
            digest.get("count").and_then(Json::as_f64),
            Some(1.0),
            "per_path.{} count",
            p.name()
        );
    }
    for st in Stage::ALL {
        let digest = parsed
            .get("per_stage")
            .and_then(|v| v.get(st.name()))
            .unwrap_or_else(|| panic!("per_stage missing {}", st.name()));
        assert_eq!(digest.get("count").and_then(Json::as_f64), Some(5.0));
    }
    // journal arrays carry whole entries
    let slow = parsed.get("slow_requests").and_then(Json::as_arr).expect("slow_requests array");
    assert_eq!(slow.len(), TracePath::COUNT);
    for e in slow {
        for k in
            ["id", "path", "queue_s", "plan_s", "pack_s", "exec_s", "gather_s", "total_s", "unix_us"]
        {
            assert!(e.get(k).is_some(), "journal entry missing {k}");
        }
    }
}

/// Every `MetricsSnapshot::FIELDS` entry must surface in the Prometheus
/// exposition under its mapped family name (scalars as `spmm_<name>`,
/// the digests as labelled histogram series, the journals as ring-depth
/// gauges).
#[test]
fn golden_prometheus_export_covers_every_snapshot_field() {
    let text = populated().snapshot().to_prometheus();
    let markers = |field: &str| -> Vec<String> {
        match field {
            "p50_s" => vec!["spmm_p50_seconds ".into()],
            "p99_s" => vec!["spmm_p99_seconds ".into()],
            "mean_latency_s" => vec!["spmm_mean_latency_seconds ".into()],
            "slow_threshold_s" => vec!["spmm_slow_threshold_seconds ".into()],
            "slow_requests" => vec!["spmm_slow_journal_entries ".into()],
            "recent_requests" => vec!["spmm_recent_journal_entries ".into()],
            "per_path" => TracePath::ALL
                .iter()
                .map(|p| format!("spmm_request_latency_seconds_bucket{{path=\"{}\"", p.name()))
                .collect(),
            "per_stage" => Stage::ALL
                .iter()
                .map(|s| format!("spmm_stage_latency_seconds_bucket{{stage=\"{}\"", s.name()))
                .collect(),
            "queue_sojourn" => vec![
                "spmm_queue_sojourn_seconds_bucket{lane=\"shard\"".into(),
                "spmm_queue_sojourn_seconds_bucket{lane=\"batch\"".into(),
            ],
            other => vec![format!("spmm_{other} ")],
        }
    };
    for field in MetricsSnapshot::FIELDS {
        for marker in markers(field) {
            assert!(
                text.contains(&marker),
                "prometheus exposition missing {marker:?} for snapshot field {field:?}"
            );
        }
    }
    // histogram series are complete: +Inf bucket, _sum, _count per label
    for p in TracePath::ALL {
        let name = p.name();
        assert!(text.contains(&format!("spmm_request_latency_seconds_bucket{{path=\"{name}\",le=\"+Inf\"}}")));
        assert!(text.contains(&format!("spmm_request_latency_seconds_count{{path=\"{name}\"}} 1")));
    }
}
