//! Observability properties: the metrics sink under concurrent hammering
//! (totals conserved, f64-bits gauges never torn, journal entries never
//! half-written), the telemetry subsystem under the same pressure
//! (worker attribution slots hammered while a reader snapshots, ring
//! entries whole), and golden export coverage — every `MetricsSnapshot`
//! field must appear in `to_json()` and `to_prometheus()` (and the
//! telemetry counters in `Display`), so a new metric cannot silently
//! miss an exporter.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use merge_spmm::coordinator::metrics::{RECENT_JOURNAL_CAP, SLOW_JOURNAL_CAP};
use merge_spmm::coordinator::telemetry::TELEMETRY_RING_CAP;
use merge_spmm::coordinator::{
    JobKind, Metrics, MetricsSnapshot, PlanEventKind, Stage, StageBreakdown, TelemetrySample,
    TracePath, WorkerStats,
};
use merge_spmm::exec::{BufferStats, ExecStats};
use merge_spmm::formats::Csr;
use merge_spmm::plan::{CacheStats, Fingerprint};
use merge_spmm::spmm::Algorithm;
use merge_spmm::util::json::Json;

/// A synthetic breakdown whose five stage durations all equal `d` and
/// whose total is exactly `5 d`, with the path index encoded in the id's
/// high bits — a reader can re-derive every field from `id` alone, so any
/// torn journal write is detectable.
fn breakdown(id: u64, path: TracePath, d: f64) -> StageBreakdown {
    let now = Instant::now();
    StageBreakdown {
        id,
        path,
        queue_s: d,
        plan_s: d,
        pack_s: d,
        exec_s: d,
        gather_s: d,
        total_s: 5.0 * d,
        admitted: now,
        plan_span: Some((now, now)),
        pack_span: Some((now, now)),
        exec_span: Some((now, now)),
        gather_span: Some((now, now)),
        shed: None,
    }
}

/// The id-derived duration the writer used (bit-exact: both sides compute
/// the same f64 expression).
fn dur_for(id: u64) -> f64 {
    1e-6 * ((id % 97) + 1) as f64
}

/// N writer threads hammer `record_trace` / `record_fused` (one path
/// each) while gauge writers flip the f64-bits gauges between two exact
/// values and a reader snapshots continuously.  Every snapshot must be
/// self-consistent: path totals only grow, p50 ≤ p99 within one copy,
/// gauges are one of the written values (never a torn bit hybrid), and
/// every journal entry satisfies its id-derived invariants.
#[test]
fn prop_concurrent_recording_conserves_totals_and_never_tears() {
    const PER_THREAD: u64 = 2000;
    let metrics = Arc::new(Metrics::new());
    // 1 µs — sub-µs would truncate to 0 in the µs-integer store and
    // disable the ring; every synthetic total here is ≥ 5 µs
    metrics.set_slow_threshold_s(1e-6);
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        let writers: Vec<_> = TracePath::ALL
            .into_iter()
            .enumerate()
            .map(|(t, path)| {
                let m = Arc::clone(&metrics);
                s.spawn(move || {
                    for i in 0..PER_THREAD {
                        let id = ((t as u64) << 32) | i;
                        m.record_trace(&breakdown(id, path, dur_for(id)));
                        if i % 64 == 0 {
                            m.record_fused(4, 32);
                        }
                    }
                })
            })
            .collect();

        let gauge_writer = {
            let m = Arc::clone(&metrics);
            let st = Arc::clone(&stop);
            s.spawn(move || {
                let cache = CacheStats { hits: 1, misses: 2, evictions: 0, len: 3 };
                let mut flip = false;
                while !st.load(Ordering::Relaxed) {
                    m.sync_plan_gauges(&cache, if flip { 1.25 } else { 2.5 });
                    m.sync_shard_gauges(4, if flip { 1.0 } else { 2.0 });
                    flip = !flip;
                }
            })
        };

        let reader = {
            let m = Arc::clone(&metrics);
            let st = Arc::clone(&stop);
            s.spawn(move || {
                let mut last_total = 0u64;
                let mut snaps = 0u64;
                while !st.load(Ordering::Relaxed) {
                    let snap = m.snapshot();
                    let total: u64 = snap.per_path.iter().map(|p| p.count).sum();
                    assert!(total >= last_total, "path totals went backwards");
                    last_total = total;
                    // both percentiles derive from ONE histogram copy, so
                    // they can never invert within a snapshot
                    for p in snap.per_path.iter().chain(&snap.per_stage) {
                        assert!(p.p50_s <= p.p99_s + 1e-12, "p50 > p99 in one snapshot");
                    }
                    // f64 gauges are stored as whole bit patterns: any read
                    // sees a written value (or the constructor default),
                    // never a torn hybrid
                    assert!(
                        [1.25, 2.5, merge_spmm::spmm::DEFAULT_THRESHOLD]
                            .contains(&snap.tuner_threshold),
                        "torn tuner_threshold gauge: {}",
                        snap.tuner_threshold
                    );
                    assert!(
                        [1.0, 2.0].contains(&snap.shard_imbalance_last),
                        "torn shard_imbalance gauge: {}",
                        snap.shard_imbalance_last
                    );
                    // journal entries are whole-struct writes under the
                    // mutex: the id-derived identities must hold bit-exactly
                    for e in snap.slow_requests.iter().chain(&snap.recent_requests) {
                        let d = dur_for(e.id);
                        assert_eq!(e.queue_s.to_bits(), d.to_bits(), "torn journal entry");
                        assert_eq!(e.total_s.to_bits(), (5.0 * d).to_bits(), "torn journal entry");
                        assert_eq!(e.path.index() as u64, e.id >> 32, "entry path/id mismatch");
                    }
                    snaps += 1;
                }
                snaps
            })
        };

        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        gauge_writer.join().unwrap();
        assert!(reader.join().unwrap() > 0, "reader never snapshotted");
    });

    // conservation: every recorded trace landed in exactly one path bucket
    // and one bucket of every stage histogram
    let snap = metrics.snapshot();
    for p in TracePath::ALL {
        assert_eq!(snap.per_path[p.index()].count, PER_THREAD, "path {} count", p.name());
    }
    for st in Stage::ALL {
        assert_eq!(
            snap.per_stage[st.index()].count,
            5 * PER_THREAD,
            "stage {} count",
            st.name()
        );
    }
    // fused counters: 5 threads × ⌈2000/64⌉ batches of 4 riders, width 32
    assert_eq!(snap.fused_batches, 5 * 32);
    assert_eq!(snap.fused_requests, 5 * 32 * 4);
    assert_eq!(snap.fused_width_mean, 32.0);
    // rings at capacity, never beyond
    assert_eq!(snap.slow_requests.len(), SLOW_JOURNAL_CAP);
    assert_eq!(snap.recent_requests.len(), RECENT_JOURNAL_CAP);
}

/// The mean's denominator is the histogram's own total — not `completed`,
/// which counts different events (regression test for the old mismatch
/// where a request could complete without recording a latency, skewing
/// the mean toward zero).
#[test]
fn mean_latency_uses_histogram_total_as_denominator() {
    let m = Metrics::new();
    m.completed.store(100, Ordering::Relaxed); // unrelated event count
    for _ in 0..4 {
        m.record_latency(0.01);
    }
    let snap = m.snapshot();
    assert_eq!(snap.completed, 100);
    assert_eq!(snap.per_path[TracePath::Solo.index()].count, 4);
    // sum is tracked in integer µs: 4 × 10000µs / 4 = 0.01s exactly
    assert!(
        (snap.mean_latency_s - 0.01).abs() < 1e-9,
        "mean must be sum/total over the histogram, got {}",
        snap.mean_latency_s
    );
    // interpolated percentile lands inside the containing bucket
    assert!(snap.p50_s >= 3e-3 && snap.p50_s <= 3e-2, "p50 {} outside bucket", snap.p50_s);
}

/// A metrics sink with every field exercised: all five paths traced, a
/// fused pass, plan/shard gauges synced, a slow threshold low enough
/// that every trace journals — plus the telemetry subsystem populated
/// (one worker-attribution slot with every field non-zero, two sampler
/// ticks so delta fields have a predecessor, and two audit-journal
/// events), so the golden tests exercise the new fields non-empty.
fn populated() -> Metrics {
    let m = Metrics::new();
    m.set_slow_threshold_s(1e-6); // 1 µs: every 100 µs+ synthetic trace journals
    for (i, path) in TracePath::ALL.into_iter().enumerate() {
        m.record_trace(&breakdown(i as u64, path, 1e-4 * (i + 1) as f64));
    }
    m.record_fused(4, 32);
    m.sync_plan_gauges(&CacheStats { hits: 3, misses: 2, evictions: 1, len: 2 }, 9.35);
    m.sync_shard_gauges(4, 1.5);
    // per-worker attribution: one slot, every field non-zero
    let w = Arc::new(WorkerStats::new());
    w.note_job(JobKind::Solo);
    w.note_jobs(JobKind::Fused, 4);
    w.note_job(JobKind::Shard);
    w.note_queue_wait(0, 5);
    w.note_queue_wait(1, 7);
    w.note_run(0, 11);
    w.note_run(1, 13);
    w.note_depth(3);
    m.register_worker_stats(vec![w]);
    // two sampler ticks: the second sample's deltas diff against the first
    let exec = ExecStats { workers: 2, parked: 1, jobs: 6, buffers: BufferStats::default() };
    m.record_sample(m.sample_now(&exec, 1, 2));
    m.record_sample(m.sample_now(&exec, 0, 1));
    // audit journal: a miss then a hit on the same fingerprint
    let fp = Fingerprint::of(&Csr::random(64, 64, 3.0, 7));
    m.plan_journal().push(PlanEventKind::CacheMiss, fp, Some(Algorithm::MergeBased), 9.35, 0);
    m.plan_journal().push(PlanEventKind::CacheHit, fp, Some(Algorithm::MergeBased), 9.35, 0);
    m
}

/// `to_json()` must parse with the crate's own parser and its top-level
/// key set must equal `MetricsSnapshot::FIELDS` exactly — both directions,
/// so a new snapshot field without an export (or a stale export) fails.
#[test]
fn golden_json_export_covers_every_snapshot_field() {
    let snap = populated().snapshot();
    let parsed = Json::parse(&snap.to_json()).expect("to_json must be parseable");
    let Json::Obj(map) = &parsed else { panic!("to_json top level must be an object") };
    let got: BTreeSet<&str> = map.keys().map(String::as_str).collect();
    let want: BTreeSet<&str> = MetricsSnapshot::FIELDS.iter().copied().collect();
    assert_eq!(got, want, "to_json keys must match MetricsSnapshot::FIELDS exactly");

    // nested digests are keyed by path/stage name and carry the full shape
    for p in TracePath::ALL {
        let digest = parsed
            .get("per_path")
            .and_then(|v| v.get(p.name()))
            .unwrap_or_else(|| panic!("per_path missing {}", p.name()));
        for k in ["count", "mean_s", "p50_s", "p99_s", "buckets", "sum_us"] {
            assert!(digest.get(k).is_some(), "per_path.{} missing {k}", p.name());
        }
        assert_eq!(
            digest.get("count").and_then(Json::as_f64),
            Some(1.0),
            "per_path.{} count",
            p.name()
        );
    }
    for st in Stage::ALL {
        let digest = parsed
            .get("per_stage")
            .and_then(|v| v.get(st.name()))
            .unwrap_or_else(|| panic!("per_stage missing {}", st.name()));
        assert_eq!(digest.get("count").and_then(Json::as_f64), Some(5.0));
    }
    // journal arrays carry whole entries
    let slow = parsed.get("slow_requests").and_then(Json::as_arr).expect("slow_requests array");
    assert_eq!(slow.len(), TracePath::COUNT);
    for e in slow {
        for k in
            ["id", "path", "queue_s", "plan_s", "pack_s", "exec_s", "gather_s", "total_s", "unix_us"]
        {
            assert!(e.get(k).is_some(), "journal entry missing {k}");
        }
    }
    // telemetry arrays carry the full shapes too
    let ws = parsed.get("worker_stats").and_then(Json::as_arr).expect("worker_stats array");
    assert_eq!(ws.len(), 1);
    for k in [
        "worker", "jobs_solo", "jobs_fused", "jobs_shard", "busy_us", "queue_wait_shard_us",
        "queue_wait_batch_us", "run_shard_us", "run_batch_us", "depth_hwm",
    ] {
        assert!(ws[0].get(k).is_some(), "worker_stats entry missing {k}");
    }
    let tel = parsed.get("telemetry").and_then(Json::as_arr).expect("telemetry array");
    assert_eq!(tel.len(), 2);
    for k in [
        "unix_us", "queue_shard_depth", "queue_batch_depth", "workers_busy", "buffers_pooled",
        "completed", "interval_us", "completed_delta", "shed_delta", "plan_hit_rate",
    ] {
        assert!(tel[1].get(k).is_some(), "telemetry sample missing {k}");
    }
    // second tick diffs against the first: a real (non-zero) interval
    assert!(
        tel[1].get("interval_us").and_then(Json::as_f64).unwrap_or(-1.0) >= 0.0
            && tel[0].get("interval_us").and_then(Json::as_f64) == Some(0.0),
        "delta fields must diff against the preceding ring entry only"
    );
    let ev = parsed.get("plan_events").and_then(Json::as_arr).expect("plan_events array");
    assert_eq!(ev.len(), 2);
    for k in ["unix_us", "kind", "fingerprint", "d", "algorithm", "threshold", "detail", "reason"] {
        assert!(ev[0].get(k).is_some(), "plan event missing {k}");
    }
    assert_eq!(
        ev[0].get("kind").and_then(Json::as_str),
        Some("cache_miss"),
        "events export in push order"
    );
    assert_eq!(ev[1].get("kind").and_then(Json::as_str), Some("cache_hit"));
}

/// Every `MetricsSnapshot::FIELDS` entry must surface in the Prometheus
/// exposition under its mapped family name (scalars as `spmm_<name>`,
/// the digests as labelled histogram series, the journals as ring-depth
/// gauges).
#[test]
fn golden_prometheus_export_covers_every_snapshot_field() {
    let text = populated().snapshot().to_prometheus();
    let markers = |field: &str| -> Vec<String> {
        match field {
            "p50_s" => vec!["spmm_p50_seconds ".into()],
            "p99_s" => vec!["spmm_p99_seconds ".into()],
            "mean_latency_s" => vec!["spmm_mean_latency_seconds ".into()],
            "net_drain_s" => vec!["spmm_net_drain_seconds ".into()],
            "slow_threshold_s" => vec!["spmm_slow_threshold_seconds ".into()],
            "slow_requests" => vec!["spmm_slow_journal_entries ".into()],
            "recent_requests" => vec!["spmm_recent_journal_entries ".into()],
            "per_path" => TracePath::ALL
                .iter()
                .map(|p| format!("spmm_request_latency_seconds_bucket{{path=\"{}\"", p.name()))
                .collect(),
            "per_stage" => Stage::ALL
                .iter()
                .map(|s| format!("spmm_stage_latency_seconds_bucket{{stage=\"{}\"", s.name()))
                .collect(),
            "queue_sojourn" => vec![
                "spmm_queue_sojourn_seconds_bucket{lane=\"shard\"".into(),
                "spmm_queue_sojourn_seconds_bucket{lane=\"batch\"".into(),
            ],
            "worker_stats" => vec![
                "spmm_worker_jobs{worker=\"0\",kind=\"solo\"} ".into(),
                "spmm_worker_busy_seconds{worker=\"0\"} ".into(),
                "spmm_worker_queue_wait_seconds{worker=\"0\",lane=\"shard\"} ".into(),
                "spmm_worker_run_seconds{worker=\"0\",lane=\"batch\"} ".into(),
                "spmm_worker_queue_depth_hwm{worker=\"0\"} ".into(),
            ],
            "telemetry" => vec!["spmm_telemetry_samples ".into()],
            "plan_events" => vec![
                "spmm_plan_journal_entries ".into(),
                "spmm_plan_events{kind=\"cache_hit\"} ".into(),
            ],
            other => vec![format!("spmm_{other} ")],
        }
    };
    for field in MetricsSnapshot::FIELDS {
        for marker in markers(field) {
            assert!(
                text.contains(&marker),
                "prometheus exposition missing {marker:?} for snapshot field {field:?}"
            );
        }
    }
    // histogram series are complete: +Inf bucket, _sum, _count per label
    for p in TracePath::ALL {
        let name = p.name();
        assert!(text.contains(&format!("spmm_request_latency_seconds_bucket{{path=\"{name}\",le=\"+Inf\"}}")));
        assert!(text.contains(&format!("spmm_request_latency_seconds_count{{path=\"{name}\"}} 1")));
    }
}

/// Every family in the exposition must carry exactly one `# HELP` and
/// one `# TYPE` header, and every header must belong to a family that
/// actually emits samples — both directions, so an orphan header or a
/// headerless family fails.  Histogram series (`_bucket`/`_sum`/`_count`)
/// fold back to their base family name, as Prometheus parses them.
#[test]
fn golden_prometheus_every_family_has_exactly_one_help_and_type() {
    let text = populated().snapshot().to_prometheus();
    let mut help: BTreeMap<String, usize> = BTreeMap::new();
    let mut typ: BTreeMap<String, usize> = BTreeMap::new();
    let mut families: BTreeSet<String> = BTreeSet::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().expect("HELP line names a family");
            *help.entry(name.into()).or_insert(0) += 1;
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let name = rest.split_whitespace().next().expect("TYPE line names a family");
            *typ.entry(name.into()).or_insert(0) += 1;
        } else if !line.trim().is_empty() {
            let name = line.split(['{', ' ']).next().expect("sample line names a series");
            let family = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .unwrap_or(name);
            families.insert(family.into());
        }
    }
    assert!(families.len() > 40, "suspiciously few families: {}", families.len());
    for f in &families {
        assert_eq!(help.get(f), Some(&1), "family {f} must have exactly one # HELP line");
        assert_eq!(typ.get(f), Some(&1), "family {f} must have exactly one # TYPE line");
    }
    for name in help.keys().chain(typ.keys()) {
        assert!(families.contains(name), "header for {name} but no samples emitted");
    }
}

/// The `Display` one-liner surfaces the telemetry counters (ring depths,
/// worker count, queue/buffer high-water marks) alongside the classic
/// fields — the third encoding of the export spine.
#[test]
fn display_surfaces_telemetry_counters() {
    let text = populated().snapshot().to_string();
    for needle in ["hwm=", "bufhwm=", "wrk=1", "tel=2", "ev=2"] {
        assert!(text.contains(needle), "Display missing {needle:?} in {text:?}");
    }
}

/// N workers hammer their attribution slots while a reader snapshots
/// through the registered `Metrics`: per-location counters only grow, no
/// snapshot exceeds the final totals, and after the writers join every
/// slot holds exactly what its owner recorded (totals conserved — the
/// aggregate over workers equals workers × per-worker writes).
#[test]
fn prop_worker_stats_concurrent_attribution_conserves_totals() {
    const WORKERS: usize = 4;
    const PER: u64 = 4000;
    let metrics = Arc::new(Metrics::new());
    let slots: Vec<Arc<WorkerStats>> =
        (0..WORKERS).map(|_| Arc::new(WorkerStats::new())).collect();
    metrics.register_worker_stats(slots.clone());
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        let writers: Vec<_> = slots
            .iter()
            .map(|w| {
                let w = Arc::clone(w);
                s.spawn(move || {
                    for i in 0..PER {
                        w.note_job(JobKind::Solo);
                        w.note_jobs(JobKind::Fused, 2);
                        w.note_queue_wait(1, 3);
                        w.note_run(1, 5);
                        w.note_depth(i % 17);
                    }
                })
            })
            .collect();
        let reader = {
            let m = Arc::clone(&metrics);
            let st = Arc::clone(&stop);
            s.spawn(move || {
                let mut last = 0u64;
                let mut snaps = 0u64;
                while !st.load(Ordering::Relaxed) {
                    let snap = m.snapshot();
                    assert_eq!(snap.worker_stats.len(), WORKERS, "table tracks every worker");
                    // each counter is a single monotonic location, so the
                    // aggregate over workers can never go backwards
                    let total: u64 = snap.worker_stats.iter().map(|w| w.jobs_total()).sum();
                    assert!(total >= last, "attribution totals went backwards");
                    last = total;
                    for w in &snap.worker_stats {
                        assert!(w.jobs_solo <= PER, "jobs_solo overshoot: {}", w.jobs_solo);
                        assert!(w.jobs_fused <= 2 * PER, "jobs_fused overshoot");
                        assert_eq!(w.jobs_shard, 0, "nobody recorded shard jobs");
                        assert!(w.queue_wait_batch_us <= 3 * PER);
                        assert!(w.run_batch_us <= 5 * PER && w.busy_us <= 5 * PER);
                        assert!(w.depth_hwm <= 16, "hwm beyond any written depth");
                    }
                    snaps += 1;
                }
                snaps
            })
        };
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        assert!(reader.join().unwrap() > 0, "reader never snapshotted");
    });

    // after the joins every slot is exact, and the exported table equals
    // the per-slot snapshots (per-worker sums == aggregate)
    let snap = metrics.snapshot();
    let direct: Vec<_> = slots.iter().enumerate().map(|(i, w)| w.snapshot(i)).collect();
    assert_eq!(snap.worker_stats, direct);
    for w in &snap.worker_stats {
        assert_eq!((w.jobs_solo, w.jobs_fused, w.jobs_shard), (PER, 2 * PER, 0));
        assert_eq!((w.queue_wait_shard_us, w.queue_wait_batch_us), (0, 3 * PER));
        assert_eq!((w.run_shard_us, w.run_batch_us, w.busy_us), (0, 5 * PER, 5 * PER));
        assert_eq!(w.depth_hwm, 16);
    }
    let total: u64 = snap.worker_stats.iter().map(|w| w.jobs_total()).sum();
    assert_eq!(total, WORKERS as u64 * 3 * PER);
}

/// A sampler thread pushes samples with id-derived field identities while
/// a reader snapshots: every exported ring entry satisfies the identities
/// bit-exactly (whole-entry memcpy — never torn), entries stay in push
/// order, and the ring never exceeds its capacity.
#[test]
fn prop_telemetry_ring_entries_never_torn() {
    const TICKS: u64 = 4000;
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        let writer = {
            let m = Arc::clone(&metrics);
            s.spawn(move || {
                for i in 1..=TICKS {
                    // every field derives from i: a torn entry breaks an identity
                    m.record_sample(TelemetrySample {
                        unix_us: i,
                        queue_shard_depth: i,
                        queue_batch_depth: 2 * i,
                        workers_busy: i % 5,
                        workers_parked: 4 - (i % 5).min(4),
                        buffers_pooled: i % 3,
                        plan_hits: 3 * i,
                        plan_misses: 7 * i,
                        completed: 5 * i,
                        shed: i,
                        cancelled: 0,
                        deadline_missed: 0,
                    });
                }
            })
        };
        let reader = {
            let m = Arc::clone(&metrics);
            let st = Arc::clone(&stop);
            s.spawn(move || {
                let mut snaps = 0u64;
                while !st.load(Ordering::Relaxed) {
                    let snap = m.snapshot();
                    assert!(snap.telemetry.len() <= TELEMETRY_RING_CAP);
                    let mut prev = 0u64;
                    for t in &snap.telemetry {
                        let i = t.unix_us;
                        assert!(i > prev, "ring entries out of push order");
                        prev = i;
                        assert_eq!(
                            (t.queue_shard_depth, t.queue_batch_depth, t.completed, t.shed),
                            (i, 2 * i, 5 * i, i),
                            "torn telemetry ring entry"
                        );
                        assert_eq!((t.plan_hits, t.plan_misses), (3 * i, 7 * i));
                    }
                    snaps += 1;
                }
                snaps
            })
        };
        writer.join().unwrap();
        stop.store(true, Ordering::Relaxed);
        assert!(reader.join().unwrap() > 0, "reader never snapshotted");
    });

    let snap = metrics.snapshot();
    assert_eq!(snap.telemetry.len(), TELEMETRY_RING_CAP, "ring retains exactly its capacity");
    assert_eq!(snap.telemetry.last().unwrap().unix_us, TICKS, "newest tick survives");
    assert_eq!(snap.telemetry[0].unix_us, TICKS - TELEMETRY_RING_CAP as u64 + 1);
}
