//! Wire-protocol properties and front-door integration tests.
//!
//! Codec half (pure, no sockets): the decoder is a *total* function —
//! deterministic pseudo-random byte streams never panic it and never make
//! it over-read; every truncation reports `Incomplete`; every corrupted
//! payload byte is flagged as a CRC mismatch; and the on-wire layout of
//! every frame type is pinned byte-for-byte, so an accidental format
//! change fails loudly instead of silently breaking old clients.
//!
//! Socket half (loopback): upload + submit round-trips bitwise against
//! in-process execution, typed errors for unknown artifacts/requests,
//! malformed-frame isolation (the neighbor connection keeps working),
//! accept-time shedding at `max_conns`, the detach guarantee (a dead
//! connection never cancels in-flight work), and idempotent resubmit
//! after reconnect.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use merge_spmm::coordinator::{EngineConfig, Server, ServerConfig};
use merge_spmm::formats::Csr;
use merge_spmm::gen;
use merge_spmm::net::frame::{self, crc32, DecodeError};
use merge_spmm::net::{
    Client, ClientConfig, ErrCode, ErrorPayload, Frame, FrameType, NetConfig, NetServer,
    ResultPayload, SubmitPayload, UploadPayload, WireOutcome,
};

// ---------------------------------------------------------------------------
// codec properties
// ---------------------------------------------------------------------------

/// Deterministic LCG so the fuzz sweep is reproducible.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    fn byte(&mut self) -> u8 {
        (self.next() >> 56) as u8
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

#[test]
fn decoder_is_total_over_arbitrary_byte_streams() {
    let mut rng = Lcg(0x5eed_0001);
    for _ in 0..4000 {
        let len = rng.below(192);
        let mut buf: Vec<u8> = (0..len).map(|_| rng.byte()).collect();
        // Bias half the streams toward valid-looking prefixes so the
        // deeper branches (type, flags, length, crc) get fuzzed too.
        if len >= 8 && rng.below(2) == 0 {
            buf[0..4].copy_from_slice(b"SPMM");
            buf[4] = 1;
            buf[5] = rng.below(16) as u8;
            if rng.below(2) == 0 {
                buf[6] = 0;
                buf[7] = 0;
            }
        }
        let max = [64u32, 1024, frame::DEFAULT_MAX_FRAME][rng.below(3)];
        match frame::decode(&buf, max) {
            Ok((fr, used)) => {
                // exactly one frame, never a byte more
                assert_eq!(used, frame::HEADER_LEN + fr.payload.len());
                assert!(used <= buf.len(), "decoder consumed bytes it never had");
            }
            Err(DecodeError::Incomplete { need }) => {
                assert!(need > buf.len(), "Incomplete must ask for more than it was given");
            }
            Err(_) => {} // typed rejection is always acceptable
        }
    }
}

#[test]
fn every_truncation_of_a_valid_frame_reports_incomplete() {
    let payload = SubmitPayload {
        deadline_ms: 99,
        artifact: "graph".into(),
        n: 2,
        b: vec![1.0, 2.0, 3.0, 4.0],
    }
    .encode();
    let full = Frame { kind: FrameType::Submit, id: 31337, payload }.encode();
    for cut in 0..full.len() {
        match frame::decode(&full[..cut], frame::DEFAULT_MAX_FRAME) {
            Err(DecodeError::Incomplete { need }) => {
                assert!(need > cut, "cut {cut}: need {need} must exceed what was given");
                assert!(need <= full.len(), "cut {cut}: need {need} beyond the real frame");
            }
            other => panic!("cut {cut}: expected Incomplete, got {other:?}"),
        }
    }
    // the untruncated frame round-trips
    let (fr, used) = frame::decode(&full, frame::DEFAULT_MAX_FRAME).unwrap();
    assert_eq!(used, full.len());
    assert_eq!(fr.id, 31337);
}

#[test]
fn every_corrupted_payload_byte_is_flagged_as_bad_crc() {
    let payload = ErrorPayload {
        code: ErrCode::Exec,
        retry_after_ms: 0,
        message: "executor failure".into(),
    }
    .encode();
    let clean = Frame { kind: FrameType::Error, id: 5, payload }.encode();
    for i in frame::HEADER_LEN..clean.len() {
        for flip in [0x01u8, 0x80, 0xff] {
            let mut bytes = clean.clone();
            bytes[i] ^= flip;
            assert!(
                matches!(
                    frame::decode(&bytes, frame::DEFAULT_MAX_FRAME),
                    Err(DecodeError::BadCrc { .. })
                ),
                "payload byte {i} flipped by {flip:#x} must fail the checksum"
            );
        }
    }
}

#[test]
fn header_corruptions_yield_their_typed_errors() {
    let clean = Frame::empty(FrameType::Poll, 1).encode();
    let case = |mutate: fn(&mut Vec<u8>)| {
        let mut b = clean.clone();
        mutate(&mut b);
        frame::decode(&b, frame::DEFAULT_MAX_FRAME)
    };
    assert!(matches!(case(|b| b[0] = b'X'), Err(DecodeError::BadMagic)));
    assert!(matches!(case(|b| b[4] = 9), Err(DecodeError::BadVersion(9))));
    assert!(matches!(case(|b| b[5] = 200), Err(DecodeError::BadType(200))));
    assert!(matches!(case(|b| b[6] = 1), Err(DecodeError::BadFlags(1))));
    // declared length beyond the guard is rejected before any read
    let mut big = clean.clone();
    big[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(frame::decode(&big, 1024), Err(DecodeError::TooLarge { .. })));
}

/// The on-wire layout, pinned byte-for-byte. Any diff here is a wire
/// format break: old clients stop interoperating. Bump [`frame::VERSION`]
/// instead of editing the expectations.
#[test]
fn golden_on_wire_layout_of_every_frame_type() {
    // Submit id=7: deadline 250 ms, artifact "A", n=2, B=[1.0, -2.0]
    let submit = Frame {
        kind: FrameType::Submit,
        id: 7,
        payload: SubmitPayload {
            deadline_ms: 250,
            artifact: "A".into(),
            n: 2,
            b: vec![1.0, -2.0],
        }
        .encode(),
    }
    .encode();
    assert_eq!(
        submit,
        &[
            0x53, 0x50, 0x4d, 0x4d, 0x01, 0x01, 0x00, 0x00, // magic "SPMM", v1, Submit, flags
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id 7
            0x17, 0x00, 0x00, 0x00, // payload len 23
            0x23, 0x79, 0x7a, 0x52, // crc32
            0xfa, 0x00, 0x00, 0x00, // deadline_ms 250
            0x01, 0x00, 0x41, // name len 1, "A"
            0x02, 0x00, 0x00, 0x00, // n 2
            0x02, 0x00, 0x00, 0x00, // b len 2
            0x00, 0x00, 0x80, 0x3f, // 1.0f32
            0x00, 0x00, 0x00, 0xc0, // -2.0f32
        ]
    );

    // UploadArtifact id=8: "M", 1×2, nnz 2, row_ptr [0,2], cols [0,1],
    // vals [1.5, 2.5]
    let upload = Frame {
        kind: FrameType::UploadArtifact,
        id: 8,
        payload: UploadPayload {
            name: "M".into(),
            m: 1,
            k: 2,
            row_ptr: vec![0, 2],
            col_idx: vec![0, 1],
            vals: vec![1.5, 2.5],
        }
        .encode(),
    }
    .encode();
    assert_eq!(
        upload,
        &[
            0x53, 0x50, 0x4d, 0x4d, 0x01, 0x02, 0x00, 0x00, // header: UploadArtifact
            0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id 8
            0x27, 0x00, 0x00, 0x00, // payload len 39
            0x6a, 0x2a, 0x8a, 0x81, // crc32
            0x01, 0x00, 0x4d, // name len 1, "M"
            0x01, 0x00, 0x00, 0x00, // m 1
            0x02, 0x00, 0x00, 0x00, // k 2
            0x02, 0x00, 0x00, 0x00, // nnz 2
            0x00, 0x00, 0x00, 0x00, 0x02, 0x00, 0x00, 0x00, // row_ptr [0, 2]
            0x00, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00, // col_idx [0, 1]
            0x00, 0x00, 0xc0, 0x3f, 0x00, 0x00, 0x20, 0x40, // vals [1.5, 2.5]
        ]
    );

    // Result id=7: merge-based, 7 µs, C=[1.0]
    let result = Frame {
        kind: FrameType::Result,
        id: 7,
        payload: ResultPayload { algorithm: 1, latency_us: 7, c: vec![1.0] }.encode(),
    }
    .encode();
    assert_eq!(
        result,
        &[
            0x53, 0x50, 0x4d, 0x4d, 0x01, 0x06, 0x00, 0x00, // header: Result
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id 7
            0x11, 0x00, 0x00, 0x00, // payload len 17
            0x63, 0x77, 0xff, 0xf2, // crc32
            0x01, // algorithm 1 (merge-based)
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // latency_us 7
            0x01, 0x00, 0x00, 0x00, // c len 1
            0x00, 0x00, 0x80, 0x3f, // 1.0f32
        ]
    );

    // Error id=7: ShedCodel, retry after 50 ms, "busy"
    let error = Frame {
        kind: FrameType::Error,
        id: 7,
        payload: ErrorPayload {
            code: ErrCode::ShedCodel,
            retry_after_ms: 50,
            message: "busy".into(),
        }
        .encode(),
    }
    .encode();
    assert_eq!(
        error,
        &[
            0x53, 0x50, 0x4d, 0x4d, 0x01, 0x07, 0x00, 0x00, // header: Error
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id 7
            0x0b, 0x00, 0x00, 0x00, // payload len 11
            0xa0, 0x6e, 0xa2, 0x2a, // crc32
            0x02, // code 2 (ShedCodel)
            0x32, 0x00, 0x00, 0x00, // retry_after_ms 50
            0x04, 0x00, 0x62, 0x75, 0x73, 0x79, // msg len 4, "busy"
        ]
    );

    // Empty-payload frames: header only, len 0, crc32("") == 0.
    for (kind, byte, id) in [
        (FrameType::Poll, 0x03u8, 0x0102030405060708u64),
        (FrameType::Cancel, 0x04, 9),
        (FrameType::Stats, 0x05, 10),
        (FrameType::Pending, 0x08, 9),
        (FrameType::Ack, 0x0a, 8),
    ] {
        let bytes = Frame::empty(kind, id).encode();
        let mut want = vec![0x53, 0x50, 0x4d, 0x4d, 0x01, byte, 0x00, 0x00];
        want.extend_from_slice(&id.to_le_bytes());
        want.extend_from_slice(&[0u8; 8]); // len 0, crc 0
        assert_eq!(bytes, want, "{kind:?} layout drifted");
    }

    // and the checksum itself is the standard IEEE CRC32
    assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
}

// ---------------------------------------------------------------------------
// loopback integration
// ---------------------------------------------------------------------------

fn cpu_cfg() -> EngineConfig {
    EngineConfig {
        artifacts_dir: None,
        threshold: 9.35,
        cpu_workers: 2,
        ..Default::default()
    }
}

/// A front door over a batching-off server on an ephemeral loopback port.
fn start_net(cfg: NetConfig) -> NetServer {
    let server =
        Server::start(cpu_cfg(), ServerConfig { max_batch: 1, ..Default::default() }).unwrap();
    NetServer::start(server, cfg).unwrap()
}

/// Fault-free in-process reference result for `C = A·B`.
fn baseline(a: &Arc<Csr>, b: &Arc<Vec<f32>>, n: usize) -> Vec<f32> {
    let s = Server::start(cpu_cfg(), ServerConfig { max_batch: 1, ..Default::default() }).unwrap();
    let c = s.submit_blocking(Arc::clone(a), Arc::clone(b), n).unwrap().c.into_vec();
    s.shutdown();
    c
}

/// Read frames off a raw socket until one decodes.
fn read_one_frame(s: &mut TcpStream) -> Frame {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    loop {
        match frame::decode(&buf, frame::DEFAULT_MAX_FRAME) {
            Ok((fr, _)) => return fr,
            Err(DecodeError::Incomplete { .. }) => {}
            Err(e) => panic!("protocol error from server: {e}"),
        }
        let n = s.read(&mut tmp).expect("socket read");
        assert!(n > 0, "connection closed before a frame arrived");
        buf.extend_from_slice(&tmp[..n]);
    }
}

#[test]
fn upload_submit_roundtrip_matches_in_process_execution() {
    // d ≈ 4 keeps the matrix outside the probe band: execution is
    // deterministic, so the wire result must be bitwise-identical.
    let a = Arc::new(Csr::random(120, 120, 4.0, 77));
    let b = Arc::new(gen::dense_matrix(120, 8, 78));
    let want = baseline(&a, &b, 8);

    let net = start_net(NetConfig::default());
    let mut client = Client::new(net.local_addr().to_string(), ClientConfig::default());
    client.upload("a0", &a).unwrap();
    match client.request("a0", b.as_slice(), 8, 0).unwrap() {
        WireOutcome::Result(r) => {
            assert_eq!(r.c.len(), want.len());
            assert!(
                r.c.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
                "wire result must be bitwise-identical to in-process execution"
            );
        }
        WireOutcome::Error(e) => panic!("wire request failed: {:?}: {}", e.code, e.message),
    }
    let snap = net.shutdown();
    assert_eq!(snap.completed, 1, "{snap}");
    assert!(snap.conns_accepted >= 1, "{snap}");
    assert!(snap.frames_in >= 2 && snap.frames_out >= 2, "{snap}");
}

#[test]
fn unknown_artifact_poll_and_cancel_yield_typed_errors() {
    let net = start_net(NetConfig::default());
    let mut client = Client::new(net.local_addr().to_string(), ClientConfig::default());
    // submit against an artifact nobody uploaded
    let out = client.request("ghost", &[1.0; 8], 8, 0).unwrap();
    assert_eq!(out.err_code(), Some(ErrCode::UnknownArtifact));
    // poll / cancel ids the server is not holding
    client.poll(4242).unwrap();
    assert_eq!(client.wait(4242).unwrap().err_code(), Some(ErrCode::UnknownRequest));
    client.cancel(4343).unwrap();
    assert_eq!(client.wait(4343).unwrap().err_code(), Some(ErrCode::UnknownRequest));
    // the stats frame returns the full JSON snapshot, wire counters included
    let stats = client.stats().unwrap();
    assert!(stats.contains("\"frames_in\""), "{stats}");
    assert!(stats.contains("\"conns_open\""), "{stats}");
    net.shutdown();
}

#[test]
fn malformed_frames_are_isolated_to_their_connection() {
    let net = start_net(NetConfig::default());
    let addr = net.local_addr();
    let a = Arc::new(Csr::random(60, 60, 4.0, 5));
    let b = Arc::new(gen::dense_matrix(60, 4, 6));
    let mut good = Client::new(addr.to_string(), ClientConfig::default());
    good.upload("a", &a).unwrap();

    // hostile neighbor: 64 bytes of junk instead of a frame
    let mut bad = TcpStream::connect(addr).unwrap();
    bad.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    bad.write_all(&[b'X'; 64]).unwrap();
    let fr = read_one_frame(&mut bad);
    assert_eq!(fr.kind, FrameType::Error);
    let e = ErrorPayload::parse(&fr.payload).unwrap();
    assert_eq!(e.code, ErrCode::Malformed);
    // …and the server closes only that connection
    let mut rest = Vec::new();
    let _ = bad.read_to_end(&mut rest);

    // the well-behaved neighbor is unaffected, before and after
    let out = good.request("a", b.as_slice(), 4, 0).unwrap();
    assert!(out.is_ok(), "healthy connection must survive a hostile neighbor");
    let snap = net.shutdown();
    assert!(snap.wire_errors >= 1, "{snap}");
    assert_eq!(snap.completed, 1, "{snap}");
}

#[test]
fn connections_beyond_max_conns_are_shed_with_overloaded() {
    let net = start_net(NetConfig { max_conns: 1, ..NetConfig::default() });
    let addr = net.local_addr();
    let a = Arc::new(Csr::random(40, 40, 4.0, 3));
    let mut first = Client::new(addr.to_string(), ClientConfig::default());
    first.upload("a", &a).unwrap(); // guarantees the first slot is held

    let mut second = TcpStream::connect(addr).unwrap();
    second.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let fr = read_one_frame(&mut second);
    assert_eq!(fr.kind, FrameType::Error);
    assert_eq!(fr.id, 0, "accept-time sheds are not tied to a request id");
    let e = ErrorPayload::parse(&fr.payload).unwrap();
    assert_eq!(e.code, ErrCode::Overloaded);
    assert!(e.code.retryable() && e.retry_after_ms > 0, "shed must carry a retry hint");
    let snap = net.shutdown();
    assert_eq!(snap.conns_shed, 1, "{snap}");
}

#[test]
fn dropping_the_connection_mid_request_does_not_cancel_it() {
    let net = start_net(NetConfig::default());
    let addr = net.local_addr();
    let a = Arc::new(Csr::random(150, 150, 4.0, 21));
    let b = gen::dense_matrix(150, 8, 22);
    {
        let mut client = Client::new(addr.to_string(), ClientConfig::default());
        client.upload("a", &a).unwrap();
        client.submit("a", &b, 8, 0).unwrap();
        // client dropped here: its TCP connection closes with the request
        // still in flight
    }
    // the registry holds a *detached* handle, so the request still runs
    let t0 = Instant::now();
    while net.metrics().completed < 1 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "request was lost with its connection"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let snap = net.shutdown();
    assert_eq!(snap.completed, 1, "{snap}");
    assert_eq!(snap.cancelled, 0, "a dead connection must not cancel in-flight work: {snap}");
}

#[test]
fn resubmitting_the_same_id_after_reconnect_delivers_the_result() {
    let net = start_net(NetConfig::default());
    let addr = net.local_addr();
    let a = Arc::new(Csr::random(80, 80, 4.0, 31));
    let b = gen::dense_matrix(80, 4, 32);
    let mut client = Client::new(addr.to_string(), ClientConfig::default());
    client.upload("a", &a).unwrap();

    let payload =
        SubmitPayload { deadline_ms: 0, artifact: "a".into(), n: 4, b: b.clone() }.encode();
    let bytes = Frame { kind: FrameType::Submit, id: 4242, payload }.encode();
    {
        // first connection dies right after submitting
        let mut s1 = TcpStream::connect(addr).unwrap();
        s1.write_all(&bytes).unwrap();
        s1.flush().unwrap();
    }
    // wait until that submit reached the engine — its registry insert
    // happened strictly before (same critical section), so the replay
    // below deterministically either re-attaches to the in-flight request
    // or re-executes a finished one; both must deliver here
    let t0 = Instant::now();
    while net.metrics().requests < 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "first submit never dispatched");
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut s2 = TcpStream::connect(addr).unwrap();
    s2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s2.write_all(&bytes).unwrap();
    s2.flush().unwrap();
    let fr = read_one_frame(&mut s2);
    assert_eq!(fr.id, 4242, "reply must carry the client's request id");
    assert_eq!(fr.kind, FrameType::Result, "resubmit after reconnect must yield the result");
    net.shutdown();
}
