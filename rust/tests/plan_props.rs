//! Property tests for the adaptive planning subsystem: fingerprint
//! stability, LRU eviction, persistence round-trips, and — the core
//! acceptance property — tuner convergence onto the oracle crossover from
//! a deliberately wrong starting threshold.

use merge_spmm::formats::Csr;
use merge_spmm::gen;
use merge_spmm::plan::{
    persist, ExecutionPlan, Fingerprint, OnlineTuner, PlanCache, Planner, THRESHOLD_MAX,
    THRESHOLD_MIN,
};
use merge_spmm::spmm::Algorithm;
use merge_spmm::util::XorShift;

fn arb_csr(rng: &mut XorShift) -> Csr {
    let m = 1 + rng.below(120);
    let k = 1 + rng.below(120);
    let mut row_ptr = vec![0usize];
    let mut col_idx = Vec::new();
    for _ in 0..m {
        let len = match rng.below(4) {
            0 => 0,
            1 => rng.below(k.min(6) + 1),
            2 => rng.below(k.min(24) + 1),
            _ => k.min(rng.below(40)),
        };
        col_idx.extend(rng.distinct_sorted(len, k));
        row_ptr.push(col_idx.len());
    }
    let vals = (0..col_idx.len()).map(|_| rng.normal()).collect();
    Csr::new(m, k, row_ptr, col_idx, vals).unwrap()
}

#[test]
fn fingerprint_stable_across_clone_and_rebuild() {
    let mut rng = XorShift::new(0xF1);
    for _ in 0..200 {
        let a = arb_csr(&mut rng);
        let fp = Fingerprint::of(&a);
        assert_eq!(fp, Fingerprint::of(&a.clone()));
        let rebuilt = Csr::new(
            a.m,
            a.k,
            a.row_ptr.clone(),
            a.col_idx.to_vec(),
            a.vals.to_vec(),
        )
        .unwrap();
        assert_eq!(fp, Fingerprint::of(&rebuilt));
    }
}

#[test]
fn fingerprint_survives_persist_round_trip() {
    let mut rng = XorShift::new(0xF2);
    let plans: Vec<(Fingerprint, ExecutionPlan)> = (0..50)
        .map(|i| {
            let a = arb_csr(&mut rng);
            (
                Fingerprint::of(&a),
                ExecutionPlan {
                    algorithm: if i % 2 == 0 {
                        Algorithm::MergeBased
                    } else {
                        Algorithm::RowSplit
                    },
                    granularity: 1 + rng.below(10_000),
                    bucket: (i % 3 == 0).then(|| format!("bucket_{i}")),
                    workers: rng.below(8),
                    partition: None,
                },
            )
        })
        .collect();
    let text = persist::to_json(9.35, &plans);
    let file = persist::parse(&text).unwrap();
    assert_eq!(file.plans, plans);
}

#[test]
fn lru_eviction_respects_recency_under_load() {
    let cache = PlanCache::new(32);
    let mut rng = XorShift::new(0xF3);
    let keys: Vec<Fingerprint> = (0..64)
        .map(|_| Fingerprint::of(&arb_csr(&mut rng)))
        .collect();
    let plan = ExecutionPlan {
        algorithm: Algorithm::MergeBased,
        granularity: 1,
        bucket: None,
        workers: 0,
        partition: None,
    };
    // fill to capacity with the first 32 distinct keys
    let mut inserted = Vec::new();
    for &k in &keys {
        if inserted.contains(&k) {
            continue;
        }
        cache.insert(k, plan.clone());
        inserted.push(k);
        if inserted.len() == 32 {
            break;
        }
    }
    assert_eq!(cache.len(), 32);
    // keep the first 8 hot, then insert fresh keys: victims must all come
    // from the cold tail, never the hot set
    let hot = &inserted[..8];
    for (i, &k) in keys.iter().rev().take(16).enumerate() {
        for &h in hot {
            assert!(cache.get(&h).is_some(), "hot key evicted at step {i}");
        }
        if !inserted.contains(&k) {
            cache.insert(k, plan.clone());
        }
    }
    for &h in hot {
        assert!(cache.get(&h).is_some(), "hot key missing at end");
    }
    assert!(cache.stats().evictions > 0);
}

#[test]
fn planner_save_load_yields_identical_plans() {
    let dir = std::env::temp_dir().join("merge_spmm_plan_props");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.json");

    let planner = Planner::new(9.35, 64, 2);
    let mut rng = XorShift::new(0xF4);
    let mats: Vec<Csr> = (0..20).map(|_| arb_csr(&mut rng)).collect();
    for a in &mats {
        planner.plan(a, None);
    }
    planner.save(&path).unwrap();

    let restored = Planner::load(&path, 64, 2).unwrap();
    assert_eq!(restored.tuner().threshold(), planner.tuner().threshold());
    assert_eq!(restored.cache().entries(), planner.cache().entries());
    // every matrix replans to a cache hit with the identical plan
    for a in &mats {
        let orig = planner.plan(a, None);
        let warm = restored.plan(a, None);
        assert!(warm.cache_hit);
        assert_eq!(warm.plan, orig.plan);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance property: seeded with a deliberately wrong threshold
/// (2.0), A/B observations from a latency oracle whose crossover sits at
/// the paper's 9.35 must pull the threshold to within ±25 % of 9.35 —
/// while never leaving the [1, 100] clamp.
#[test]
fn tuner_converges_to_paper_threshold_on_synthetic_suite() {
    // Synthetic suite: exact-row-length matrices bracketing the crossover
    // (the reg_* slice of the 157-matrix suite, scaled down for test
    // speed).  d is exactly the row length.
    let lens = [2usize, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 14, 16, 20, 26, 34];
    let suite: Vec<Csr> = lens
        .iter()
        .map(|&l| gen::uniform_rows(64, l, Some(256), 0xF5 + l as u64))
        .collect();

    // Latency oracle calibrated so the crossover is exactly d = 9.35:
    // merge time grows with d, row-split is flat (the paper's Fig. 5
    // shape), with ±3 % deterministic noise.
    let mut rng = XorShift::new(0xF6);
    let mut noisy = |base: f64| base * (0.97 + 0.06 * rng.f32() as f64);

    let tuner = OnlineTuner::with_params(2.0, 0.5, 1, 0.35);
    assert_eq!(tuner.threshold(), 2.0);
    let mut moved_toward = 0usize;
    for _ in 0..300 {
        for a in &suite {
            let d = a.mean_row_length();
            // production gating: only boundary traffic is probed
            if !tuner.should_probe(d) {
                continue;
            }
            let before = (tuner.threshold() - 9.35).abs();
            tuner.observe(d, noisy(1.0), noisy(d / 9.35));
            let after = tuner.threshold();
            assert!(
                (THRESHOLD_MIN..=THRESHOLD_MAX).contains(&after),
                "threshold escaped clamp: {after}"
            );
            if (after - 9.35).abs() < before {
                moved_toward += 1;
            }
        }
    }
    let learned = tuner.threshold();
    let err = (learned - 9.35).abs() / 9.35;
    assert!(
        err <= 0.25,
        "tuner failed to converge: learned {learned:.2}, error {:.0}%",
        err * 100.0
    );
    // the trajectory overwhelmingly moved toward the oracle
    assert!(
        moved_toward > 0,
        "tuner never moved toward the oracle crossover"
    );
    assert!(tuner.stats().probes > 0);
}

/// End-to-end: the engine's hot path consults the cache — a warm engine
/// plans the same request without a second miss, across both algorithms.
#[test]
fn engine_hot_path_uses_plan_cache() {
    use merge_spmm::coordinator::SpmmEngine;
    let eng = SpmmEngine::cpu_only(9.35, 2);
    let short = Csr::random(300, 300, 4.0, 0xF7);
    let long = gen::uniform_rows(300, 24, Some(300), 0xF8);
    let b = gen::dense_matrix(300, 8, 0xF9);
    for a in [&short, &long] {
        for pass in 0..3 {
            let r = eng.spmm(a, &b, 8).unwrap();
            assert_eq!(r.cache_hit, pass > 0);
        }
    }
    let snap = eng.metrics.snapshot();
    assert_eq!(snap.plan_misses, 2);
    assert_eq!(snap.plan_hits, 4);
    assert_eq!(snap.merge, 3);
    assert_eq!(snap.rowsplit, 3);
}
