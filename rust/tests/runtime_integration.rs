//! End-to-end integration: PJRT artifacts vs CPU executors.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! stays green on a fresh checkout).  This is the contract test for the
//! whole three-layer stack: the numbers produced by the AOT-compiled
//! Pallas kernels running under PJRT must match the Rust CPU executors,
//! which in turn are tested against the textbook reference.

use std::path::PathBuf;

use merge_spmm::coordinator::{EngineConfig, ExecutionPath, SpmmEngine};
use merge_spmm::formats::Csr;
use merge_spmm::gen;
use merge_spmm::spmm::{self, Algorithm};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn engine() -> Option<SpmmEngine> {
    let dir = artifacts_dir()?;
    Some(
        SpmmEngine::new(EngineConfig {
            artifacts_dir: Some(dir),
            ..Default::default()
        })
        .expect("engine must load when artifacts exist"),
    )
}

fn assert_close(got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len());
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() < tol * (1.0 + y.abs()),
            "idx {i}: {x} vs {y}"
        );
    }
}

#[test]
fn rowsplit_artifact_matches_cpu() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // long rows → heuristic picks row-split → rowsplit bucket
    let a = gen::uniform_rows(500, 20, Some(800), 2001);
    let b = gen::dense_matrix(800, 64, 2002);
    let r = eng.spmm(&a, &b, 64).unwrap();
    assert_eq!(r.algorithm, Algorithm::RowSplit);
    assert_eq!(r.path, ExecutionPath::Pjrt, "bucket should fit");
    assert!(r.bucket.as_deref().unwrap_or("").contains("rowsplit"));
    let want = spmm::spmm_reference(&a, &b, 64);
    assert_close(&r.c, &want, 1e-3);
}

#[test]
fn merge_artifact_matches_cpu() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // short rows → merge-based → merge bucket
    let a = Csr::random(900, 900, 4.0, 2003);
    let b = gen::dense_matrix(900, 64, 2004);
    let r = eng.spmm(&a, &b, 64).unwrap();
    assert_eq!(r.algorithm, Algorithm::MergeBased);
    assert_eq!(r.path, ExecutionPath::Pjrt);
    assert!(r.bucket.as_deref().unwrap_or("").contains("merge"));
    let want = spmm::spmm_reference(&a, &b, 64);
    assert_close(&r.c, &want, 1e-3);
}

#[test]
fn oversize_matrix_falls_back_to_cpu() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // larger than any bucket → CPU fallback, still correct
    let a = Csr::random(6000, 6000, 3.0, 2005);
    let b = gen::dense_matrix(6000, 16, 2006);
    let r = eng.spmm(&a, &b, 16).unwrap();
    assert_eq!(r.path, ExecutionPath::CpuFallback);
    let want = spmm::spmm_reference(&a, &b, 16);
    assert_close(&r.c, &want, 1e-3);
}

#[test]
fn empty_rows_and_boundary_rows_through_pjrt() {
    let Some(eng) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // adversarial: empty rows + a row of exactly 32 (ELL width boundary)
    let mut row_ptr = vec![0usize];
    let mut col_idx: Vec<u32> = Vec::new();
    for i in 0..200 {
        let len = match i % 4 {
            0 => 0,
            1 => 32,
            2 => 1,
            _ => 7,
        };
        for j in 0..len {
            col_idx.push(((i * 13 + j * 29) % 600) as u32);
        }
        row_ptr.push(col_idx.len());
    }
    // sort each row's columns
    let mut sorted = col_idx.clone();
    for w in 0..200 {
        sorted[row_ptr[w]..row_ptr[w + 1]].sort_unstable();
    }
    let vals: Vec<f32> = (0..sorted.len()).map(|i| (i % 17) as f32 * 0.25 - 2.0).collect();
    let a = Csr::new(200, 600, row_ptr, sorted, vals).unwrap();
    let b = gen::dense_matrix(600, 64, 2007);
    let r = eng.spmm(&a, &b, 64).unwrap();
    assert_eq!(r.path, ExecutionPath::Pjrt);
    let want = spmm::spmm_reference(&a, &b, 64);
    assert_close(&r.c, &want, 1e-3);
}

#[test]
fn gcn_artifact_runs_end_to_end() {
    use merge_spmm::runtime::Runtime;
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = Runtime::load_filtered(&dir, |a| a.entry == "gcn_fwd").unwrap();
    let art = rt
        .manifest()
        .by_entry("gcn_fwd")
        .next()
        .expect("gcn artifact missing")
        .clone();
    let name = art.name.clone();
    let (m, ell, f, h, o) = (
        art.meta_usize("m").unwrap(),
        art.meta_usize("ell").unwrap(),
        art.meta_usize("f").unwrap(),
        art.meta_usize("h").unwrap(),
        art.meta_usize("o").unwrap(),
    );
    // adjacency: banded graph padded into the bucket
    let g = gen::banded(m, 4, 10, 2008);
    let ellv = merge_spmm::formats::Ell::from_csr_padded(&g, ell).unwrap();
    let cols: Vec<i32> = ellv.col_idx.iter().map(|&c| c as i32).collect();
    let x = gen::dense_matrix(m, f, 2009);
    let w1 = gen::dense_matrix(f, h, 2010);
    let w2 = gen::dense_matrix(h, o, 2011);
    let args = vec![
        Runtime::literal_i32(&cols, &[m, ell]).unwrap(),
        Runtime::literal_f32(&ellv.vals, &[m, ell]).unwrap(),
        Runtime::literal_f32(&x, &[m, f]).unwrap(),
        Runtime::literal_f32(&w1, &[f, h]).unwrap(),
        Runtime::literal_f32(&w2, &[h, o]).unwrap(),
    ];
    let out = rt.execute(&name, &args).unwrap();
    assert_eq!(out.len(), m * o);
    // CPU oracle: ReLU((A·X)·W1)·W2
    let ax = spmm::spmm_reference(&g, &x, f);
    let mut hmat = merge_spmm::spmm::dense::gemm(&ax, &w1, m, f, h, 0);
    for v in hmat.iter_mut() {
        *v = v.max(0.0);
    }
    let want = merge_spmm::spmm::dense::gemm(&hmat, &w2, m, h, o, 0);
    assert_close(&out, &want, 5e-3);
}

#[test]
fn spmv_artifacts_match_cpu() {
    use merge_spmm::runtime::Runtime;
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = Runtime::load_filtered(&dir, |a| a.entry.starts_with("spmv")).unwrap();
    // row-split SpMV
    let art = rt.manifest().by_entry("spmv_rowsplit").next().cloned();
    if let Some(art) = art {
        let name = art.name.clone();
        let (m, k, ell) = (
            art.meta_usize("m").unwrap(),
            art.meta_usize("k").unwrap(),
            art.meta_usize("ell").unwrap(),
        );
        let a = merge_spmm::gen::uniform_rows(m, 8, Some(k), 2012);
        let ellv = merge_spmm::formats::Ell::from_csr_padded(&a, ell).unwrap();
        let cols: Vec<i32> = ellv.col_idx.iter().map(|&c| c as i32).collect();
        let x = gen::dense_matrix(k, 1, 2013);
        let out = rt
            .execute(
                &name,
                &[
                    Runtime::literal_i32(&cols, &[m, ell]).unwrap(),
                    Runtime::literal_f32(&ellv.vals, &[m, ell]).unwrap(),
                    Runtime::literal_f32(&x, &[k]).unwrap(),
                ],
            )
            .unwrap();
        assert_close(&out, &spmm::spmv_reference(&a, &x), 1e-3);
    }
    // merge SpMV
    let art = rt.manifest().by_entry("spmv_merge").next().cloned();
    if let Some(art) = art {
        let name = art.name.clone();
        let (m, k, z) = (
            art.meta_usize("m").unwrap(),
            art.meta_usize("k").unwrap(),
            art.meta_usize("nnz_pad").unwrap(),
        );
        let a = Csr::random(m, k, 5.0, 2014);
        let flat = merge_spmm::formats::Coo::flatten_padded(&a, z).unwrap();
        let ri: Vec<i32> = flat.row_idx.iter().map(|&r| r as i32).collect();
        let ci: Vec<i32> = flat.col_idx.iter().map(|&c| c as i32).collect();
        let x = gen::dense_matrix(k, 1, 2015);
        let out = rt
            .execute(
                &name,
                &[
                    Runtime::literal_i32(&ri, &[z]).unwrap(),
                    Runtime::literal_i32(&ci, &[z]).unwrap(),
                    Runtime::literal_f32(&flat.vals, &[z]).unwrap(),
                    Runtime::literal_f32(&x, &[k]).unwrap(),
                ],
            )
            .unwrap();
        assert_close(&out, &spmm::spmv_reference(&a, &x), 1e-3);
    }
}
