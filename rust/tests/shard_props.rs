//! Property tests for the sharding subsystem: gathering per-shard results
//! must reproduce the reference exactly, shard cuts must come from the
//! merge-path coordinates with bounded imbalance, and the scatter-gather
//! composition must be **bitwise**-identical to the unsharded executor run
//! over the concatenated partition.

use std::sync::Arc;

use merge_spmm::coordinator::{EngineConfig, Server, ServerConfig};
use merge_spmm::exec::{partition, Executor};
use merge_spmm::formats::Csr;
use merge_spmm::gen;
use merge_spmm::loadbalance::validate_segments;
use merge_spmm::shard::{
    concat_partitions, cuts_valid, imbalance, shard_cuts, ShardPolicy, ShardedEngine,
};
use merge_spmm::spmm::{
    merge_spmm_into, rowsplit_spmm_into, spmm_reference, Algorithm,
};
use merge_spmm::util::XorShift;

fn arb_csr(rng: &mut XorShift) -> Csr {
    let m = 1 + rng.below(120);
    let k = 1 + rng.below(80);
    let mut row_ptr = vec![0usize];
    let mut col_idx = Vec::new();
    for _ in 0..m {
        let len = match rng.below(4) {
            0 => 0,
            1 => rng.below(4),
            2 => rng.below(k.min(50)),
            _ => k.min(rng.below(k + 1)),
        };
        col_idx.extend(rng.distinct_sorted(len, k));
        row_ptr.push(col_idx.len());
    }
    let vals = (0..col_idx.len()).map(|_| rng.normal()).collect();
    Csr::new(m, k, row_ptr, col_idx, vals).unwrap()
}

fn assert_close(got: &[f32], want: &[f32], case: usize, what: &str) {
    assert_eq!(got.len(), want.len(), "case {case} {what}");
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() < 2e-3 * (1.0 + y.abs()),
            "case {case} {what} idx {i}: {x} vs {y}"
        );
    }
}

/// Execute every shard with its own partition into its row range of one
/// output (the scatter-gather composition, synchronously), returning the
/// gathered output and the per-shard partitions used.
fn gather_shards(
    a: &Csr,
    cuts: &[usize],
    b: &[f32],
    n: usize,
    alg: Algorithm,
    p: usize,
) -> (Vec<f32>, Vec<Vec<merge_spmm::loadbalance::Segment>>) {
    let exec = Executor::new(2);
    let mut ctx = exec.make_ctx();
    let mut c = vec![f32::NAN; a.m * n]; // poison: every element must be written
    let mut parts = Vec::new();
    for w in cuts.windows(2) {
        let shard = a.shard_view(w[0], w[1]);
        let segs = partition(&shard, alg, p);
        let out = &mut c[w[0] * n..w[1] * n];
        if shard.nnz() == 0 {
            out.fill(0.0);
        } else {
            match alg {
                Algorithm::RowSplit => rowsplit_spmm_into(&shard, b, n, &segs, &mut ctx, out),
                Algorithm::MergeBased => merge_spmm_into(&shard, b, n, &segs, &mut ctx, out),
            }
        }
        parts.push(segs);
    }
    (c, parts)
}

/// Gather(shard results) == reference, and the gathered output is
/// bitwise-identical to the unsharded executor run over the concatenation
/// of the per-shard partitions — for random matrices, both algorithms,
/// assorted shard counts.
#[test]
fn prop_gather_matches_reference_and_unsharded_bitwise() {
    let mut rng = XorShift::new(0xC31);
    for case in 0..100 {
        let a = arb_csr(&mut rng);
        let n = [1, 4, 9, 16][rng.below(4)];
        let shards = 1 + rng.below(6);
        let skew = rng.below(2) == 1;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let cuts = shard_cuts(&a, shards, skew, 1.25);
        assert!(cuts_valid(&a, &cuts), "case {case}: {cuts:?}");
        let want = spmm_reference(&a, &b, n);
        for alg in [Algorithm::RowSplit, Algorithm::MergeBased] {
            let p = 1 + rng.below(4);
            let (gathered, parts) = gather_shards(&a, &cuts, &b, n, alg, p);
            assert_close(&gathered, &want, case, "gathered");
            // bitwise: unsharded executor over the concatenated partition
            if a.nnz() > 0 {
                let merged = concat_partitions(&a, &cuts, &parts);
                validate_segments(&a, &merged).unwrap();
                let exec = Executor::new(2);
                let mut ctx = exec.make_ctx();
                let mut unsharded = vec![f32::NAN; a.m * n];
                match alg {
                    Algorithm::RowSplit => {
                        rowsplit_spmm_into(&a, &b, n, &merged, &mut ctx, &mut unsharded)
                    }
                    Algorithm::MergeBased => {
                        merge_spmm_into(&a, &b, n, &merged, &mut ctx, &mut unsharded)
                    }
                }
                assert!(
                    gathered.iter().zip(&unsharded).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "case {case} {alg}: sharded result must be bitwise-identical"
                );
            }
        }
    }
}

/// Adversarial shapes: a single dense row, power-law rows, all-empty
/// shard ranges, shards = 1, and shards > rows — through the full
/// concurrent [`ShardedEngine`].
#[test]
fn prop_adversarial_shapes_through_the_engine() {
    let cases: Vec<(&str, Csr)> = vec![
        ("single-dense-row", {
            let cols: Vec<u32> = (0..3000).collect();
            Csr::new(1, 3000, vec![0, 3000], cols, vec![0.5; 3000]).unwrap()
        }),
        ("power-law", gen::power_law(2500, 1.2, 700, 0xC35)),
        ("empty-runs", {
            // dense blocks separated by long all-empty runs, so some
            // shards are entirely empty rows
            let m = 1200usize;
            let mut row_ptr = vec![0usize];
            let mut cols: Vec<u32> = Vec::new();
            for i in 0..m {
                if (i / 100) % 3 == 0 {
                    cols.extend((0..8u32).map(|c| (c + i as u32) % 64));
                }
                row_ptr.push(cols.len());
            }
            let vals = vec![1.0f32; cols.len()];
            Csr::new(m, 64, row_ptr, cols, vals).unwrap()
        }),
        ("all-empty", Csr::empty(900, 40)),
        ("tiny", Csr::random(3, 10, 2.0, 0xC36)),
    ];
    for (name, a) in cases {
        let a = Arc::new(a);
        let n = 8;
        let b = Arc::new(gen::dense_matrix(a.k, n, 0xC37));
        let want = spmm_reference(&a, &b, n);
        for shards in [1usize, 2, 5, 16] {
            let eng = ShardedEngine::cpu_only(ShardPolicy::fixed(shards), 4, 2);
            let r = eng.spmm(&a, &b, n).unwrap();
            assert_close(&r.c, &want, shards, name);
            assert!(r.shards <= shards.max(1) && r.shards >= 1);
            if shards == 16 {
                assert!(r.shards <= a.m.max(1), "{name}: at most one shard per row");
            }
        }
    }
}

/// Balanced-mode imbalance bound: on matrices whose rows are small
/// relative to the per-shard budget (the regime balanced mode is for),
/// max/mean nnz stays within the policy bound of 1.25.
#[test]
fn prop_balanced_imbalance_within_policy_bound() {
    let mut rng = XorShift::new(0xC32);
    for case in 0..60 {
        // uniform-ish rows: max row length stays far below nnz/shards
        let m = 400 + rng.below(800);
        let k = 200 + rng.below(200);
        let avg = 4.0 + rng.below(8) as f64;
        let a = Csr::random(m, k, avg, 0xC33 + case as u64);
        if a.nnz() == 0 {
            continue;
        }
        for shards in [2usize, 3, 4, 6] {
            // precondition of the bound: no single row dominates a shard
            if (a.max_row_length() + 1) * shards * 8 > a.nnz() {
                continue;
            }
            let cuts = shard_cuts(&a, shards, false, 1.25);
            assert!(cuts_valid(&a, &cuts));
            let imb = imbalance(&a, &cuts);
            assert!(
                imb <= 1.25,
                "case {case} shards {shards}: imbalance {imb:.3} (cuts {cuts:?})"
            );
        }
    }
}

/// Skew-aware mode isolates every ultra-heavy row into a singleton shard
/// whenever the shard budget allows it (isolating H rows needs H
/// singletons plus one shard per gap; at most 2H+1 ≤ shards here), even
/// with several heavy rows scattered through the matrix — and never
/// produces more shards than requested.
#[test]
fn prop_skew_isolation() {
    let mut rng = XorShift::new(0xC34);
    for case in 0..30 {
        let m = 300 + rng.below(500);
        let k = 4096;
        let heavy_at: Vec<usize> = (0..1 + rng.below(3)).map(|_| rng.below(m)).collect();
        let mut row_ptr = vec![0usize];
        let mut cols: Vec<u32> = Vec::new();
        for i in 0..m {
            let len = if heavy_at.contains(&i) { 2048 } else { rng.below(4) };
            cols.extend((0..len as u32).map(|c| c % k as u32));
            row_ptr.push(cols.len());
        }
        let vals = vec![1.0f32; cols.len()];
        let a = Csr::new(m, k, row_ptr, cols, vals).unwrap();
        // budget 8: up to 3 heavy rows cost ≤ 3 + 4 = 7 shards, so every
        // heavy row is guaranteed its singleton
        let shards = 8;
        let cap = 1.25 * a.nnz() as f64 / shards as f64;
        let cuts = shard_cuts(&a, shards, true, 1.25);
        assert!(cuts_valid(&a, &cuts), "case {case}: {cuts:?}");
        assert!(cuts.len() - 1 <= shards, "case {case}: budget exceeded {cuts:?}");
        for i in 0..m {
            if (a.row_len(i) as f64) > cap {
                assert!(
                    cuts.contains(&i) && cuts.contains(&(i + 1)),
                    "case {case}: heavy row {i} not isolated in {cuts:?}"
                );
            }
        }
    }
    // tight budget: a dominant interior row wants isolation, but with
    // shards = 2 the singleton + its two flanking gaps would need 3 —
    // isolation degrades gracefully and the shard-count contract holds
    let m = 101usize;
    let mut row_ptr = vec![0usize];
    let mut cols: Vec<u32> = Vec::new();
    for i in 0..m {
        let len = if i == 50 { 700 } else { 3 };
        cols.extend((0..len as u32).map(|c| c % 64));
        row_ptr.push(cols.len());
    }
    let vals = vec![1.0f32; cols.len()];
    let a = Csr::new(m, 64, row_ptr, cols, vals).unwrap();
    for shards in [2usize, 3, 4] {
        let cuts = shard_cuts(&a, shards, true, 1.25);
        assert!(cuts_valid(&a, &cuts));
        assert!(cuts.len() - 1 <= shards, "shards {shards}: {cuts:?}");
    }
}

/// Shard cuts really are merge-path coordinates: in balanced mode every
/// interior cut is a row boundary whose merge-space position is as close
/// to its equally-spaced diagonal as any row boundary can be.
#[test]
fn prop_cuts_are_nearest_merge_coordinates() {
    let mut rng = XorShift::new(0xC38);
    for case in 0..40 {
        let a = arb_csr(&mut rng);
        let shards = 2 + rng.below(5);
        let cuts = shard_cuts(&a, shards, false, 1.25);
        let total = a.m + a.nnz();
        // every interior cut must be optimal for its diagonal
        let mut interior = cuts[1..cuts.len() - 1].iter().peekable();
        for s in 1..shards {
            let d = total * s / shards;
            let best = (0..=a.m)
                .map(|r| (r + a.row_ptr[r]).abs_diff(d))
                .min()
                .unwrap();
            if let Some(&&c) = interior.peek() {
                if (c + a.row_ptr[c]).abs_diff(d) == best {
                    interior.next();
                }
            }
        }
        assert!(
            interior.peek().is_none(),
            "case {case}: cuts {cuts:?} contain a non-merge-coordinate cut"
        );
    }
}

/// Mixed traffic through ONE server on ONE pool set: batched small
/// requests and sharded large requests run concurrently, results stay
/// bitwise-exact (large, row-split) / reference-close (small), the
/// resident thread count equals the batcher-only configuration (the old
/// design ran a second engine-thread set — 2× threads), and the
/// steady-state path keeps reusing pooled buffers.
#[test]
fn prop_mixed_traffic_unified_pool() {
    const WORKERS: usize = 3;
    const CPU_WORKERS: usize = 2;
    let cpu = EngineConfig {
        artifacts_dir: None,
        cpu_workers: CPU_WORKERS,
        ..Default::default()
    };
    let server_cfg = ServerConfig {
        workers: WORKERS,
        ..Default::default()
    };

    // Large request: uniform 24-nonzero rows (d = 24 → row-split on every
    // shard, and row-split is bitwise-stable under any partitioning, so
    // sharded output must equal the unsharded baseline bit for bit).
    let big = Arc::new(gen::uniform_rows(4000, 24, Some(2048), 0xD01));
    let big_b = Arc::new(gen::dense_matrix(2048, 16, 0xD02));
    // Small request: d = 4 (merge path, far from the probe band), far
    // below min_shard_work — always rides the batcher lane.
    let small = Arc::new(Csr::random(300, 300, 4.0, 0xD03));
    let small_b = Arc::new(gen::dense_matrix(300, 8, 0xD04));
    let small_want = spmm_reference(&small, &small_b, 8);

    // Baseline: sharding disabled.  Captures the bitwise reference for
    // the big matrix and the resident-thread budget of one pool set.
    let baseline = Server::start(cpu.clone(), server_cfg.clone()).unwrap();
    let resident_budget = baseline.resident_threads();
    let big_want = baseline
        .submit_blocking(Arc::clone(&big), Arc::clone(&big_b), 16)
        .unwrap()
        .c
        .into_vec();
    baseline.shutdown();

    let server = Server::start(
        EngineConfig {
            shard: ShardPolicy::auto(),
            ..cpu
        },
        server_cfg,
    )
    .unwrap();
    // one pool set serves both paths: enabling sharding adds no threads
    assert_eq!(
        server.resident_threads(),
        resident_budget,
        "sharding must not add resident threads (workers + workers×cpu_workers + router)"
    );
    assert_eq!(resident_budget, 1 + WORKERS + WORKERS * CPU_WORKERS);

    // Concurrent mixed phase: 2 clients hammer the sharded path, 2
    // clients hammer the batcher path, and 2 clients submit Arc-identical
    // small requests in concurrent pairs — same fingerprint bucket, same
    // A, so the router fuses them into wide passes while shard tasks and
    // plain batches run on the same pool.
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| {
                for _ in 0..10 {
                    let r = server
                        .submit_blocking(Arc::clone(&big), Arc::clone(&big_b), 16)
                        .unwrap();
                    assert!(r.shards >= 2, "large request must shard: {}", r.shards);
                    assert!(
                        r.c.iter().zip(&big_want).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "sharded result must stay bitwise-identical under mixed traffic"
                    );
                }
            });
        }
        for _ in 0..2 {
            s.spawn(|| {
                for _ in 0..20 {
                    let r = server
                        .submit_blocking(Arc::clone(&small), Arc::clone(&small_b), 8)
                        .unwrap();
                    assert_eq!(r.shards, 1, "small request must ride the batcher lane");
                    for (i, (x, y)) in r.c.iter().zip(&small_want).enumerate() {
                        assert!(
                            (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                            "idx {i}: {x} vs {y}"
                        );
                    }
                }
            });
        }
        for _ in 0..2 {
            s.spawn(|| {
                for _ in 0..10 {
                    // two in flight at once: the bucket holds both when the
                    // deadline fires, so they fuse into one wide pass
                    let h1 = server.submit(Arc::clone(&small), Arc::clone(&small_b), 8).unwrap();
                    let h2 = server.submit(Arc::clone(&small), Arc::clone(&small_b), 8).unwrap();
                    for h in [h1, h2] {
                        let r = h.recv().unwrap().unwrap();
                        assert_eq!(r.shards, 1);
                        for (i, (x, y)) in r.c.iter().zip(&small_want).enumerate() {
                            assert!(
                                (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                                "fused idx {i}: {x} vs {y}"
                            );
                        }
                    }
                }
            });
        }
    });

    // Steady state after the burst: both shapes are warm in the shared
    // free-list, so sequential rounds allocate nothing new.
    let _ = server.submit_blocking(Arc::clone(&big), Arc::clone(&big_b), 16).unwrap();
    let allocated_before = server.metrics().buffers_allocated;
    let reuses_before = server.metrics().buffer_reuses;
    for _ in 0..6 {
        drop(server.submit_blocking(Arc::clone(&big), Arc::clone(&big_b), 16).unwrap());
        drop(server.submit_blocking(Arc::clone(&small), Arc::clone(&small_b), 8).unwrap());
    }
    let snap = server.metrics();
    assert_eq!(
        snap.buffers_allocated, allocated_before,
        "steady-state mixed traffic must reuse pooled buffers"
    );
    assert!(snap.buffer_reuses >= reuses_before + 12, "reused {}", snap.buffer_reuses);
    // the unified gauge reports the one pool set
    assert_eq!(snap.pool_workers as usize, WORKERS * CPU_WORKERS);

    let per_worker = server.shards_per_worker();
    assert!(
        per_worker.iter().filter(|&&c| c > 0).count() >= 2,
        "shard tasks must spread across the unified pool: {per_worker:?}"
    );
    let snap = server.shutdown();
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.completed, 20 + 40 + 40 + 13);
    assert_eq!(snap.sharded, 20 + 7);
    // the paired clients kept ≥ 2 same-A requests in flight, so at least
    // some of their traffic must have executed as fused wide passes
    // alongside the sharded scatters — the fused+sharded mixed case
    assert!(snap.fused_requests >= 2, "fused {}", snap.fused_requests);
    assert!(snap.fused_batches >= 1);
    assert!(snap.fused_requests <= snap.completed);
}
