//! Property tests for the SpMM executors: all algorithms agree with the
//! textbook reference on arbitrary matrices, worker counts, and widths.

use std::sync::Arc;

use merge_spmm::exec::{partition, BufferPool, Executor, FusedStaging};
use merge_spmm::formats::{Csr, SellP};
use merge_spmm::spmm::{
    baselines, dense,
    merge::{merge_spmm_with, MergeKind},
    merge_spmm, merge_spmm_into, rowsplit_spmm, rowsplit_spmm_into, spmm_reference, Algorithm,
    TILE_WIDTH,
};
use merge_spmm::util::XorShift;

fn arb_csr(rng: &mut XorShift) -> Csr {
    let m = 1 + rng.below(80);
    let k = 1 + rng.below(80);
    let mut row_ptr = vec![0usize];
    let mut col_idx = Vec::new();
    for _ in 0..m {
        let len = match rng.below(4) {
            0 => 0,
            1 => rng.below(4),
            2 => rng.below(k.min(50)),
            _ => k.min(rng.below(k + 1)),
        };
        col_idx.extend(rng.distinct_sorted(len, k));
        row_ptr.push(col_idx.len());
    }
    let vals = (0..col_idx.len()).map(|_| rng.normal()).collect();
    Csr::new(m, k, row_ptr, col_idx, vals).unwrap()
}

fn assert_close(got: &[f32], want: &[f32], case: usize, what: &str) {
    assert_eq!(got.len(), want.len());
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() < 2e-3 * (1.0 + y.abs()),
            "case {case} {what} idx {i}: {x} vs {y}"
        );
    }
}

#[test]
fn prop_executors_match_reference() {
    let mut rng = XorShift::new(0xB21);
    for case in 0..120 {
        let a = arb_csr(&mut rng);
        let n = [1, 3, 8, 17, 32][rng.below(5)];
        let p = 1 + rng.below(9);
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let want = spmm_reference(&a, &b, n);
        assert_close(&rowsplit_spmm(&a, &b, n, p), &want, case, "rowsplit");
        assert_close(&merge_spmm(&a, &b, n, p), &want, case, "merge-nz");
        assert_close(
            &merge_spmm_with(&a, &b, n, p, MergeKind::MergePath),
            &want,
            case,
            "merge-mp",
        );
    }
}

/// Adversarial carry-out shapes for the merge executor (locks phase-2
/// correctness for both phase-1 decompositions): runs of empty rows
/// straddling segment boundaries, a single dense row shared by every
/// worker, and far more workers than nonzeros.
#[test]
fn prop_merge_adversarial_carry_out_shapes() {
    let mut rng = XorShift::new(0xB25);
    // (1) runs of empty rows placed to straddle equal-nonzero boundaries:
    // alternating blocks of empty rows and short dense runs, so nearly
    // every worker starts inside or next to an empty run
    for case in 0..40 {
        let m = 20 + rng.below(120);
        let k = 1 + rng.below(60);
        let mut row_ptr = vec![0usize];
        let mut col_idx: Vec<u32> = Vec::new();
        let mut in_empty_run = rng.below(2) == 0;
        let mut r = 0usize;
        while r < m {
            let run = 1 + rng.below(9);
            for _ in 0..run.min(m - r) {
                if !in_empty_run {
                    let len = 1 + rng.below(4);
                    col_idx.extend(rng.distinct_sorted(len, k));
                }
                row_ptr.push(col_idx.len());
                r += 1;
            }
            in_empty_run = !in_empty_run;
        }
        let vals: Vec<f32> = (0..col_idx.len()).map(|_| rng.normal()).collect();
        let a = Csr::new(m, k, row_ptr, col_idx, vals).unwrap();
        let n = [1, 4, 16][rng.below(3)];
        let p = 2 + rng.below(12);
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let want = spmm_reference(&a, &b, n);
        for kind in [MergeKind::NonzeroSplit, MergeKind::MergePath] {
            assert_close(&merge_spmm_with(&a, &b, n, p, kind), &want, case, "empty-runs");
        }
    }
    // (2) single dense row: every worker's segment lands inside row 0, so
    // the whole result is assembled from carry-outs
    for case in 0..10 {
        let k = 64 + rng.below(1000);
        let cols: Vec<u32> = (0..k as u32).collect();
        let vals: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
        let a = Csr::new(1, k, vec![0, k], cols, vals).unwrap();
        let n = [1, 8, 32][rng.below(3)];
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let want = spmm_reference(&a, &b, n);
        for p in [2, 7, 16, 64] {
            for kind in [MergeKind::NonzeroSplit, MergeKind::MergePath] {
                assert_close(&merge_spmm_with(&a, &b, n, p, kind), &want, case, "dense-row");
            }
        }
    }
    // (3) p > nnz: more workers than work items (degenerate segments)
    for case in 0..20 {
        let a = arb_csr(&mut rng);
        let n = 1 + rng.below(8);
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let want = spmm_reference(&a, &b, n);
        let p = a.nnz() + 1 + rng.below(50);
        for kind in [MergeKind::NonzeroSplit, MergeKind::MergePath] {
            assert_close(&merge_spmm_with(&a, &b, n, p, kind), &want, case, "p>nnz");
        }
    }
}

/// The fused wide pass (pack `[B_1|…|B_k]` → one `m × n_total` execution
/// → unpack column slices) must be **bitwise-identical** to executing
/// each request separately with the same algorithm and the same phase-1
/// partition — for random matrices, random batch sizes k ∈ [2, 8], mixed
/// widths including n = 1 and n > TILE_WIDTH, and both algorithms.  The
/// partition depends only on A, so sharing it across widths is exactly
/// what the serve path does (plan-cache partition replay).
#[test]
fn prop_fused_wide_pass_bitwise_identical_to_per_request() {
    let mut rng = XorShift::new(0xB31);
    let exec = Executor::new(2);
    let pool = Arc::new(BufferPool::new());
    for case in 0..60 {
        let a = arb_csr(&mut rng);
        let k = 2 + rng.below(7); // k ∈ [2, 8]
        let widths: Vec<usize> = (0..k)
            .map(|_| [1, 3, 8, 17, TILE_WIDTH + 1, 100][rng.below(6)])
            .collect();
        let n_total: usize = widths.iter().sum();
        let bs: Vec<Vec<f32>> = widths
            .iter()
            .map(|&n| (0..a.k * n).map(|_| rng.normal()).collect())
            .collect();
        let p = 1 + rng.below(6);
        for alg in [Algorithm::RowSplit, Algorithm::MergeBased] {
            let segs = partition(&a, alg, p);
            // fused: one wide pass over A
            let staging = FusedStaging::pack(
                &pool,
                a.k,
                n_total,
                bs.iter().zip(&widths).map(|(b, &n)| (b.as_slice(), n)),
            );
            let mut ctx = exec.make_ctx();
            let mut c_wide = vec![f32::NAN; a.m * n_total];
            match alg {
                Algorithm::RowSplit => {
                    rowsplit_spmm_into(&a, staging.b_wide(), n_total, &segs, &mut ctx, &mut c_wide)
                }
                Algorithm::MergeBased => {
                    merge_spmm_into(&a, staging.b_wide(), n_total, &segs, &mut ctx, &mut c_wide)
                }
            }
            let mut outs: Vec<Vec<f32>> =
                widths.iter().map(|&n| vec![f32::NAN; a.m * n]).collect();
            FusedStaging::unpack(
                &c_wide,
                a.m,
                n_total,
                outs.iter_mut().zip(&widths).map(|(o, &n)| (o.as_mut_slice(), n)),
            );
            // per-request: same algorithm, same partition, one at a time
            for ((b, &n), fused_c) in bs.iter().zip(&widths).zip(&outs) {
                let mut solo = vec![f32::NAN; a.m * n];
                match alg {
                    Algorithm::RowSplit => {
                        rowsplit_spmm_into(&a, b, n, &segs, &mut ctx, &mut solo)
                    }
                    Algorithm::MergeBased => {
                        merge_spmm_into(&a, b, n, &segs, &mut ctx, &mut solo)
                    }
                }
                assert!(
                    fused_c.iter().zip(&solo).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "case {case} {alg:?} n={n}: fused slice must match solo run bit for bit"
                );
                // and both must be numerically right
                assert_close(&solo, &spmm_reference(&a, b, n), case, "solo-vs-reference");
            }
        }
    }
}

#[test]
fn prop_baselines_match_reference() {
    let mut rng = XorShift::new(0xB22);
    for case in 0..60 {
        let a = arb_csr(&mut rng);
        let n = [2, 8, 16][rng.below(3)];
        let p = 1 + rng.below(5);
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let want = spmm_reference(&a, &b, n);
        // csrmm (column-major in/out)
        let b_cm = baselines::to_col_major(&b, a.k, n);
        let got = baselines::to_row_major(&baselines::csrmm(&a, &b_cm, n, p), a.m, n);
        assert_close(&got, &want, case, "csrmm");
        // csrmm2 (row-major in, column-major out)
        let got2 = baselines::to_row_major(&baselines::csrmm2(&a, &b, n, p), a.m, n);
        assert_close(&got2, &want, case, "csrmm2");
        // SELL-P
        let s = SellP::from_csr(&a, 1 + rng.below(16), 1 + rng.below(8));
        assert_close(&baselines::sellp_spmm(&s, &b, n, p), &want, case, "sellp");
    }
}

#[test]
fn prop_gemm_equals_spmm_on_dense_matrix() {
    let mut rng = XorShift::new(0xB23);
    for case in 0..30 {
        let m = 1 + rng.below(30);
        let k = 1 + rng.below(30);
        let n = 1 + rng.below(20);
        // fully dense CSR
        let mut row_ptr = vec![0usize];
        let mut col_idx = Vec::new();
        for _ in 0..m {
            col_idx.extend(0..k as u32);
            row_ptr.push(col_idx.len());
        }
        let vals: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let a_csr = Csr::new(m, k, row_ptr, col_idx, vals.clone()).unwrap();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let via_spmm = merge_spmm(&a_csr, &b, n, 4);
        let via_gemm = dense::gemm(&vals, &b, m, k, n, 4);
        assert_close(&via_spmm, &via_gemm, case, "dense-equivalence");
    }
}

#[test]
fn prop_linearity() {
    // SpMM is linear: A·(αB) = α(A·B)
    let mut rng = XorShift::new(0xB24);
    for case in 0..40 {
        let a = arb_csr(&mut rng);
        let n = 4;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let alpha = 2.5f32;
        let b_scaled: Vec<f32> = b.iter().map(|v| v * alpha).collect();
        let c1 = rowsplit_spmm(&a, &b_scaled, n, 2);
        let c2: Vec<f32> = rowsplit_spmm(&a, &b, n, 2).iter().map(|v| v * alpha).collect();
        assert_close(&c1, &c2, case, "linearity");
    }
}
