//! Property tests for the SpMM executors: all algorithms agree with the
//! textbook reference on arbitrary matrices, worker counts, and widths.

use merge_spmm::formats::{Csr, SellP};
use merge_spmm::spmm::{
    baselines, dense,
    merge::{merge_spmm_with, MergeKind},
    merge_spmm, rowsplit_spmm, spmm_reference,
};
use merge_spmm::util::XorShift;

fn arb_csr(rng: &mut XorShift) -> Csr {
    let m = 1 + rng.below(80);
    let k = 1 + rng.below(80);
    let mut row_ptr = vec![0usize];
    let mut col_idx = Vec::new();
    for _ in 0..m {
        let len = match rng.below(4) {
            0 => 0,
            1 => rng.below(4),
            2 => rng.below(k.min(50)),
            _ => k.min(rng.below(k + 1)),
        };
        col_idx.extend(rng.distinct_sorted(len, k));
        row_ptr.push(col_idx.len());
    }
    let vals = (0..col_idx.len()).map(|_| rng.normal()).collect();
    Csr::new(m, k, row_ptr, col_idx, vals).unwrap()
}

fn assert_close(got: &[f32], want: &[f32], case: usize, what: &str) {
    assert_eq!(got.len(), want.len());
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() < 2e-3 * (1.0 + y.abs()),
            "case {case} {what} idx {i}: {x} vs {y}"
        );
    }
}

#[test]
fn prop_executors_match_reference() {
    let mut rng = XorShift::new(0xB21);
    for case in 0..120 {
        let a = arb_csr(&mut rng);
        let n = [1, 3, 8, 17, 32][rng.below(5)];
        let p = 1 + rng.below(9);
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let want = spmm_reference(&a, &b, n);
        assert_close(&rowsplit_spmm(&a, &b, n, p), &want, case, "rowsplit");
        assert_close(&merge_spmm(&a, &b, n, p), &want, case, "merge-nz");
        assert_close(
            &merge_spmm_with(&a, &b, n, p, MergeKind::MergePath),
            &want,
            case,
            "merge-mp",
        );
    }
}

#[test]
fn prop_baselines_match_reference() {
    let mut rng = XorShift::new(0xB22);
    for case in 0..60 {
        let a = arb_csr(&mut rng);
        let n = [2, 8, 16][rng.below(3)];
        let p = 1 + rng.below(5);
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let want = spmm_reference(&a, &b, n);
        // csrmm (column-major in/out)
        let b_cm = baselines::to_col_major(&b, a.k, n);
        let got = baselines::to_row_major(&baselines::csrmm(&a, &b_cm, n, p), a.m, n);
        assert_close(&got, &want, case, "csrmm");
        // csrmm2 (row-major in, column-major out)
        let got2 = baselines::to_row_major(&baselines::csrmm2(&a, &b, n, p), a.m, n);
        assert_close(&got2, &want, case, "csrmm2");
        // SELL-P
        let s = SellP::from_csr(&a, 1 + rng.below(16), 1 + rng.below(8));
        assert_close(&baselines::sellp_spmm(&s, &b, n, p), &want, case, "sellp");
    }
}

#[test]
fn prop_gemm_equals_spmm_on_dense_matrix() {
    let mut rng = XorShift::new(0xB23);
    for case in 0..30 {
        let m = 1 + rng.below(30);
        let k = 1 + rng.below(30);
        let n = 1 + rng.below(20);
        // fully dense CSR
        let mut row_ptr = vec![0usize];
        let mut col_idx = Vec::new();
        for _ in 0..m {
            col_idx.extend(0..k as u32);
            row_ptr.push(col_idx.len());
        }
        let vals: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let a_csr = Csr::new(m, k, row_ptr, col_idx, vals.clone()).unwrap();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let via_spmm = merge_spmm(&a_csr, &b, n, 4);
        let via_gemm = dense::gemm(&vals, &b, m, k, n, 4);
        assert_close(&via_spmm, &via_gemm, case, "dense-equivalence");
    }
}

#[test]
fn prop_linearity() {
    // SpMM is linear: A·(αB) = α(A·B)
    let mut rng = XorShift::new(0xB24);
    for case in 0..40 {
        let a = arb_csr(&mut rng);
        let n = 4;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let alpha = 2.5f32;
        let b_scaled: Vec<f32> = b.iter().map(|v| v * alpha).collect();
        let c1 = rowsplit_spmm(&a, &b_scaled, n, 2);
        let c2: Vec<f32> = rowsplit_spmm(&a, &b, n, 2).iter().map(|v| v * alpha).collect();
        assert_close(&c1, &c2, case, "linearity");
    }
}
