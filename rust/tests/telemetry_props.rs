//! Telemetry-subsystem properties: the whole-entry event ring under wrap
//! pressure, and the plan-decision audit journal over a real mixed
//! serving run — every reply's algorithm decision must be explained by a
//! journal event carrying the same fingerprint the client can compute
//! for itself (the observatory's acceptance criterion).

use std::sync::Arc;

use merge_spmm::coordinator::telemetry::{EventRing, PLAN_JOURNAL_CAP};
use merge_spmm::coordinator::{
    EngineConfig, PlanEvent, PlanEventKind, PlanJournal, Server, ServerConfig,
};
use merge_spmm::formats::Csr;
use merge_spmm::gen;
use merge_spmm::plan::Fingerprint;
use merge_spmm::shard::ShardMode;
use merge_spmm::spmm::Algorithm;

#[test]
fn event_ring_wraps_keeping_newest_in_order() {
    let mut r: EventRing<u64, 8> = EventRing::new();
    assert!(r.to_vec().is_empty());
    assert_eq!(r.total(), 0);
    for i in 0..5u64 {
        r.push(i);
    }
    assert_eq!(r.to_vec(), vec![0, 1, 2, 3, 4], "below capacity nothing is lost");
    for i in 5..100u64 {
        r.push(i);
    }
    assert_eq!(r.to_vec(), (92..100).collect::<Vec<_>>(), "newest 8 retained, oldest first");
    assert_eq!(r.total(), 100, "total counts every push, not just the retained window");
}

#[test]
fn plan_journal_retains_newest_cap_entries_and_stamps_time() {
    let j = PlanJournal::default();
    let fp = Fingerprint::of(&Csr::random(32, 32, 2.0, 3));
    for i in 0..(PLAN_JOURNAL_CAP + 10) {
        j.push(PlanEventKind::CacheHit, fp, None, 9.35, i as u64);
    }
    let v = j.to_vec();
    assert_eq!(v.len(), PLAN_JOURNAL_CAP);
    assert_eq!(j.total(), PLAN_JOURNAL_CAP + 10);
    assert_eq!(v[0].detail, 10, "the 10 oldest entries were overwritten");
    assert_eq!(v.last().unwrap().detail, (PLAN_JOURNAL_CAP + 9) as u64);
    assert!(v.iter().all(|e| e.unix_us > 0), "push stamps the wall clock");
    let ordered = v.windows(2).all(|w| w[0].unix_us <= w[1].unix_us);
    assert!(ordered, "entries stay in push order");
}

/// Does any journal event keyed on `fp` satisfy `pred`?
fn any_event(events: &[PlanEvent], fp: Fingerprint, pred: fn(PlanEventKind) -> bool) -> bool {
    events.iter().any(|e| e.fingerprint == fp && pred(e.kind))
}

fn is_probe(kind: PlanEventKind) -> bool {
    matches!(kind, PlanEventKind::ProbeKept | PlanEventKind::ProbeAdjusted)
}

/// Kinds that explain a reply on their own: a probed reply may return
/// the measured winner rather than the planned algorithm (the probe
/// event IS its decision record), and a sharded reply's decision is the
/// scatter keyed on the parent fingerprint.
fn decides_reply(kind: PlanEventKind) -> bool {
    is_probe(kind) || kind == PlanEventKind::Scatter
}

/// A 32-request mixed run — solo repeats (cache miss → hits, plus a
/// near-boundary A/B probe), a fused burst (16 concurrent requests over
/// ONE `Arc`-identical matrix), and auto-sharded large requests — after
/// which the audit journal must explain every reply: for each request's
/// client-side fingerprint there is at least one journal event keyed on
/// that fingerprint, and among them one that either carries the reply's
/// algorithm or records the probe/scatter decision that produced it.
#[test]
fn audit_journal_explains_every_decision_in_a_mixed_run() {
    let mut engine_cfg = EngineConfig { artifacts_dir: None, ..Default::default() };
    engine_cfg.shard.mode = ShardMode::Auto;
    let server_cfg = ServerConfig {
        workers: 2,
        telemetry_interval: Some(std::time::Duration::from_millis(1)),
        ..Default::default()
    };
    let server = Server::start(engine_cfg, server_cfg).expect("server start");

    // d ≈ 8 sits inside the tuner's probe band (|ln(8/9.35)| < 0.5), so
    // the solo repeats trigger an A/B probe (1-in-8 cadence, first
    // boundary request included)
    let solo = Arc::new(Csr::random(300, 300, 8.0, 41));
    // fused burst target: d ≈ 4 is outside the probe band, and all 16
    // requests share one Arc so the batcher's fuser can co-batch them
    let fused = Arc::new(Csr::random(400, 400, 4.0, 42));
    // auto-shard: rows + nnz ≈ 39 000 ≫ min_shard_work, cuts into 2
    let big = Arc::new(Csr::random(3000, 3000, 12.0, 43));
    let b300 = Arc::new(gen::dense_matrix(300, 32, 7));
    let b400 = Arc::new(gen::dense_matrix(400, 32, 7));
    let b3000 = Arc::new(gen::dense_matrix(3000, 32, 7));

    let mut replies: Vec<(Fingerprint, Algorithm)> = Vec::new();
    for _ in 0..8 {
        let r = server.submit_blocking(Arc::clone(&solo), Arc::clone(&b300), 32).expect("solo");
        replies.push((Fingerprint::of(&solo), r.algorithm));
    }
    let handles: Vec<_> = (0..16)
        .map(|_| server.submit(Arc::clone(&fused), Arc::clone(&b400), 32).expect("submit"))
        .collect();
    for h in handles {
        let r = h.recv().expect("server alive").expect("fused-burst request");
        replies.push((Fingerprint::of(&fused), r.algorithm));
    }
    for _ in 0..8 {
        let r = server.submit_blocking(Arc::clone(&big), Arc::clone(&b3000), 32).expect("big");
        replies.push((Fingerprint::of(&big), r.algorithm));
    }
    assert_eq!(replies.len(), 32);

    let snap = server.shutdown();
    assert!(!snap.plan_events.is_empty(), "journal captured the run");
    assert!(snap.plan_events.len() <= PLAN_JOURNAL_CAP);
    for (fp, algorithm) in &replies {
        let matching: Vec<_> = snap.plan_events.iter().filter(|e| e.fingerprint == *fp).collect();
        assert!(!matching.is_empty(), "no journal event for fingerprint {fp:?}");
        let explained = matching
            .iter()
            .any(|e| e.algorithm == Some(*algorithm) || decides_reply(e.kind));
        assert!(explained, "no event explains algorithm {algorithm:?} for {fp:?}");
    }
    // the three traffic shapes each left their signature decision
    let solo_fp = Fingerprint::of(&solo);
    let probed = any_event(&snap.plan_events, solo_fp, is_probe);
    assert!(probed, "solo repeats near the boundary must probe");
    let replayed = any_event(&snap.plan_events, solo_fp, |k| k == PlanEventKind::CacheHit);
    assert!(replayed, "solo repeats must replay the cached plan");
    let big_fp = Fingerprint::of(&big);
    let scatter = snap.plan_events.iter().find(|e| e.kind == PlanEventKind::Scatter);
    let scattered = scatter.is_some_and(|e| e.fingerprint == big_fp && e.detail >= 2);
    assert!(scattered, "large requests must journal their scatter fan-out");
    assert!(snap.sharded >= 1, "auto mode sharded the big requests");
    assert!(snap.probes >= 1, "the boundary probe ran");
    // the sampler ticked while the big phase was in flight
    assert!(!snap.telemetry.is_empty(), "telemetry ring must have samples");
    assert!(snap.telemetry.last().unwrap().completed >= 24);
}
