//! Trace-coherence properties over the real serve paths.  Every result's
//! stage breakdown must be internally consistent — non-negative stage
//! durations summing to no more than the end-to-end wall time, with
//! `latency_s` equal to the trace total — and the per-path histograms
//! must agree with the paths the results actually report.  Fused riders
//! additionally share the batch's span endpoints while keeping their own
//! admit instants.  (The degraded path needs the `PANIC_N` fault
//! injection, which is `cfg(test)`-only, so it is covered by the
//! `workers` unit tests instead.)

use std::sync::Arc;
use std::time::Duration;

use merge_spmm::coordinator::{
    EngineConfig, Server, ServerConfig, SpmmEngine, SpmmResult, Stage, TracePath,
};
use merge_spmm::formats::Csr;
use merge_spmm::gen;
use merge_spmm::shard::ShardPolicy;

fn cpu_cfg() -> EngineConfig {
    EngineConfig { artifacts_dir: None, cpu_workers: 2, ..Default::default() }
}

fn assert_coherent(r: &SpmmResult) {
    let s = &r.stages;
    for (name, d) in [
        ("queue", s.queue_s),
        ("plan", s.plan_s),
        ("pack", s.pack_s),
        ("exec", s.exec_s),
        ("gather", s.gather_s),
    ] {
        assert!(d >= 0.0, "{name} stage must be non-negative, got {d}");
    }
    assert!(
        s.stage_sum_s() <= s.total_s + 1e-9,
        "stage sum {} exceeds end-to-end total {}",
        s.stage_sum_s(),
        s.total_s
    );
    assert_eq!(
        s.total_s.to_bits(),
        r.latency_s.to_bits(),
        "latency_s must BE the trace total, not a second measurement"
    );
}

/// Direct engine calls: solo and probe dispatches stamp queue/plan/exec
/// and the per-path and per-stage histograms count exactly what the
/// results report.
#[test]
fn prop_solo_and_probe_stages_coherent() {
    let eng = SpmmEngine::cpu_only(9.35, 2);
    let b = gen::dense_matrix(400, 8, 0xE01);
    let solo = Csr::random(400, 400, 4.0, 0xE02); // d = 4: outside the probe band
    let probe = gen::uniform_rows(400, 9, Some(400), 0xE03); // d ≈ 9: boundary

    for _ in 0..3 {
        let r = eng.spmm(&solo, &b, 8).unwrap();
        assert_eq!(r.stages.path, TracePath::Solo);
        assert_coherent(&r);
        assert!(r.stages.exec_s > 0.0, "kernel time cannot be zero");
    }
    let r = eng.spmm(&probe, &b, 8).unwrap();
    assert_eq!(r.stages.path, TracePath::Probe, "first boundary request must A/B-probe");
    assert_coherent(&r);

    let snap = eng.metrics.snapshot();
    assert_eq!(snap.per_path[TracePath::Solo.index()].count, 3);
    assert_eq!(snap.per_path[TracePath::Probe.index()].count, 1);
    // solo dispatch stamps queue/plan/exec; pack and gather belong to the
    // fused/sharded paths and must NOT be recorded as zeros here
    assert_eq!(snap.per_stage[Stage::Queue.index()].count, 4);
    assert_eq!(snap.per_stage[Stage::Plan.index()].count, 4);
    assert_eq!(snap.per_stage[Stage::Exec.index()].count, 4);
    assert_eq!(snap.per_stage[Stage::Pack.index()].count, 0);
    assert_eq!(snap.per_stage[Stage::Gather.index()].count, 0);
}

/// Through the server, the per-path histograms must count exactly the
/// paths the replies report, and with a 1ns slow threshold every request
/// journals — each entry coherent on its own.
#[test]
fn prop_server_histograms_match_observed_paths() {
    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            max_batch: 1, // no co-batching: replies are solo or probe
            slow_threshold: Duration::from_micros(1), // sub-µs truncates to "disabled"
            ..Default::default()
        },
    )
    .unwrap();
    let mats: Vec<Arc<Csr>> = (0..4)
        .map(|i| Arc::new(Csr::random(200 + i * 40, 300, 3.0 + i as f64 * 3.0, 0xE10 + i as u64)))
        .collect();
    let b = Arc::new(gen::dense_matrix(300, 8, 0xE14));

    let mut counts = [0u64; TracePath::COUNT];
    for i in 0..20 {
        let r = server.submit_blocking(Arc::clone(&mats[i % mats.len()]), Arc::clone(&b), 8).unwrap();
        assert_coherent(&r);
        counts[r.stages.path.index()] += 1;
    }
    let snap = server.shutdown();
    for p in TracePath::ALL {
        assert_eq!(
            snap.per_path[p.index()].count,
            counts[p.index()],
            "histogram vs observed replies disagree on path {}",
            p.name()
        );
    }
    // every request journalled; the recent ring keeps the newest whole
    assert!(!snap.recent_requests.is_empty());
    assert!(!snap.slow_requests.is_empty());
    for e in snap.slow_requests.iter().chain(&snap.recent_requests) {
        let sum = e.queue_s + e.plan_s + e.pack_s + e.exec_s + e.gather_s;
        assert!(sum <= e.total_s + 1e-9, "journal entry stage sum exceeds total");
    }
}

/// Co-batched riders over one `Arc`-identical A execute as ONE wide pass:
/// all four report the Fused path with *identical* plan/pack/exec/gather
/// span endpoints (the pass is the batch's work, done once), while each
/// keeps its own admit instant — so queue waits stay per-request.
#[test]
fn prop_fused_riders_share_spans_keep_own_queue_waits() {
    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(60), // flush on the 4th rider, deterministically
            ..Default::default()
        },
    )
    .unwrap();
    let a = Arc::new(Csr::random(250, 250, 4.0, 0xE21));
    let b = Arc::new(gen::dense_matrix(250, 8, 0xE22));
    let handles: Vec<_> = (0..4)
        .map(|_| server.submit(Arc::clone(&a), Arc::clone(&b), 8).unwrap())
        .collect();
    let results: Vec<SpmmResult> =
        handles.iter().map(|h| h.recv().unwrap().unwrap()).collect();

    for r in &results {
        assert_eq!(r.stages.path, TracePath::Fused);
        assert_eq!(r.fused_width, 32, "4 riders × n=8");
        assert_coherent(r);
        assert!(r.stages.pack_span.is_some(), "fused path must stamp pack");
        assert!(r.stages.gather_span.is_some(), "fused path must stamp gather");
    }
    let first = &results[0].stages;
    for r in &results[1..] {
        assert_eq!(r.stages.plan_span, first.plan_span, "riders must share the batch plan span");
        assert_eq!(r.stages.pack_span, first.pack_span, "riders must share the batch pack span");
        assert_eq!(r.stages.exec_span, first.exec_span, "riders must share the batch exec span");
        assert_eq!(
            r.stages.gather_span, first.gather_span,
            "riders must share the batch gather span"
        );
    }
    for i in 0..results.len() {
        for j in i + 1..results.len() {
            assert_ne!(
                results[i].stages.admitted, results[j].stages.admitted,
                "riders {i} and {j} must keep distinct admit instants"
            );
        }
    }
    let snap = server.shutdown();
    assert_eq!(snap.per_path[TracePath::Fused.index()].count, 4);
    assert_eq!(snap.fused_batches, 1);
}

/// Sharded scatter-gather requests report the Sharded path with all five
/// stages stamped: plan (cuts + per-shard plans), pack (lease + split),
/// exec (enqueue → last shard done), gather (reply assembly).
#[test]
fn prop_sharded_stages_coherent() {
    let server = Server::start(
        EngineConfig { shard: ShardPolicy::auto(), ..cpu_cfg() },
        ServerConfig { workers: 3, ..Default::default() },
    )
    .unwrap();
    let big = Arc::new(gen::uniform_rows(4000, 24, Some(2048), 0xE31));
    let b = Arc::new(gen::dense_matrix(2048, 16, 0xE32));
    for _ in 0..3 {
        let r = server.submit_blocking(Arc::clone(&big), Arc::clone(&b), 16).unwrap();
        assert!(r.shards >= 2, "large request must shard, got {}", r.shards);
        assert_eq!(r.stages.path, TracePath::Sharded);
        assert_coherent(&r);
        assert!(r.stages.plan_s > 0.0, "shard planning cannot be free");
        assert!(r.stages.exec_s > 0.0, "shard execution cannot be free");
        assert!(r.stages.pack_span.is_some(), "sharded path must stamp pack");
        assert!(r.stages.gather_span.is_some(), "sharded path must stamp gather");
    }
    let snap = server.shutdown();
    assert_eq!(snap.per_path[TracePath::Sharded.index()].count, 3);
    assert_eq!(snap.sharded, 3);
}
