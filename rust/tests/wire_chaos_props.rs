//! Wire chaos suite (`--features faults`): concurrent socket clients with
//! mixed deadlines and cancellations, under injected executor panics,
//! delayed reads, torn terminal frames, and mid-request connection drops.
//!
//! Invariants proved here:
//! - every wire request reaches exactly one client-side terminal outcome
//!   (result, typed error, or a bounded transport give-up — never a hang);
//! - the server-side conservation law holds in the final snapshot:
//!   `completed + errors + shed_deadline + shed_codel + cancelled ==
//!   requests` (each engine submission lands in exactly one terminal
//!   counter, no matter how many times a wire id was replayed);
//! - every survivor is bitwise-identical to a fault-free in-process run;
//! - a mid-traffic `NetServer::shutdown` drains the poll registry and
//!   joins every wire thread — no wedged connections (`conns_open == 0`).
//!
//! The fault plan is process-global, so this file holds a single test: a
//! second PLAN-touching test would race it under the parallel runner.

#![cfg(feature = "faults")]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use merge_spmm::coordinator::faults::{self, FaultPlan};
use merge_spmm::coordinator::{EngineConfig, Server, ServerConfig};
use merge_spmm::formats::Csr;
use merge_spmm::gen;
use merge_spmm::net::{Client, ClientConfig, ErrCode, NetConfig, NetServer, WireOutcome};

fn cpu_cfg() -> EngineConfig {
    EngineConfig {
        artifacts_dir: None,
        threshold: 9.35,
        cpu_workers: 2,
        ..Default::default()
    }
}

/// Clears the global fault plan even when an assert unwinds mid-test, so
/// a failure here cannot poison unit tests running in the same process.
struct ClearGuard;
impl Drop for ClearGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

/// Client-side terminal tallies; every request lands in exactly one.
#[derive(Default)]
struct Tally {
    /// delivered results (bitwise-checked against the baseline)
    ok: u64,
    /// typed shed errors: deadline-expired, codel-overload, cancelled
    shed: u64,
    /// typed execution errors (injected worker panics)
    errs: u64,
    /// typed refusals from a server already shutting down
    refused: u64,
    /// transport gave up after bounded reconnects (torn-frame loop,
    /// dropped connection, or the listener already gone)
    lost: u64,
}

impl Tally {
    fn add(&mut self, o: Tally) {
        self.ok += o.ok;
        self.shed += o.shed;
        self.errs += o.errs;
        self.refused += o.refused;
        self.lost += o.lost;
    }

    fn total(&self) -> u64 {
        self.ok + self.shed + self.errs + self.refused + self.lost
    }
}

const N_CLIENTS: usize = 4;
const PER_CLIENT: usize = 12;

#[test]
fn wire_chaos_conserves_outcomes_and_drains_cleanly() {
    // d ≈ 4 keeps every matrix outside the A/B-probe band: execution is
    // deterministic, so survivors must match the baseline bitwise even
    // when a replayed id re-executes from scratch.
    let mats: Vec<(Arc<Csr>, Arc<Vec<f32>>)> = (0..4)
        .map(|i| {
            let m = 200 + i * 40;
            let seed = 9100 + i as u64 * 10;
            (
                Arc::new(Csr::random(m, m, 4.0, seed)),
                Arc::new(gen::dense_matrix(m, 8, seed + 1)),
            )
        })
        .collect();

    // fault-free in-process baseline, batching off
    let clean = Server::start(
        cpu_cfg(),
        ServerConfig { max_batch: 1, ..Default::default() },
    )
    .unwrap();
    let baseline: Arc<Vec<Vec<f32>>> = Arc::new(
        mats.iter()
            .map(|(a, b)| {
                clean
                    .submit_blocking(Arc::clone(a), Arc::clone(b), 8)
                    .unwrap()
                    .c
                    .into_vec()
            })
            .collect(),
    );
    clean.shutdown();

    // the front door over a small, contended engine
    let server = Server::start(
        cpu_cfg(),
        ServerConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_capacity: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let net = NetServer::start(server, NetConfig::default()).unwrap();
    let addr = net.local_addr().to_string();

    // artifacts go up before the faults come on, so setup is reliable and
    // the chaos phase targets exactly the request path
    {
        let mut setup = Client::new(addr.clone(), ClientConfig::default());
        for (i, (a, _)) in mats.iter().enumerate() {
            setup.upload(&format!("m{i}"), a).unwrap();
        }
    }

    let _guard = ClearGuard;
    faults::install(FaultPlan {
        seed: 0x3173_C4A0,
        panic_one_in: 7,
        delay_one_in: 4,
        delay: Duration::from_millis(2),
        torn_one_in: 5,
        drop_conn_one_in: 6,
        ..FaultPlan::default()
    });

    let outcomes = Arc::new(AtomicU64::new(0));
    let mats = Arc::new(mats);
    let clients: Vec<_> = (0..N_CLIENTS)
        .map(|t| {
            let addr = addr.clone();
            let mats = Arc::clone(&mats);
            let baseline = Arc::clone(&baseline);
            let outcomes = Arc::clone(&outcomes);
            std::thread::spawn(move || {
                // tight reconnect budget keeps an always-torn id bounded:
                // the client gives up (counted `lost`) instead of hanging
                let mut client = Client::new(
                    addr,
                    ClientConfig {
                        max_reconnects: 6,
                        backoff_base: Duration::from_millis(5),
                        backoff_cap: Duration::from_millis(100),
                        ..ClientConfig::default()
                    },
                );
                let mut tally = Tally::default();
                for j in 0..PER_CLIENT {
                    let idx = (t + j) % mats.len();
                    let (_, b) = &mats[idx];
                    // mixed deadlines: none / tight / generous
                    let deadline_ms = match j % 3 {
                        0 => 0,
                        1 => 1,
                        _ => 30_000,
                    };
                    let sub = client.submit(&format!("m{idx}"), b.as_slice(), 8, deadline_ms);
                    let id = match sub {
                        Ok(id) => id,
                        Err(_) => {
                            tally.lost += 1;
                            // ordering: relaxed — progress counter for the test driver
                            outcomes.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    };
                    if j % 6 == 5 {
                        let _ = client.cancel(id);
                    }
                    match client.wait(id) {
                        Ok(WireOutcome::Result(r)) => {
                            let want = &baseline[idx];
                            assert_eq!(r.c.len(), want.len(), "request {t}/{j}: wrong shape");
                            assert!(
                                r.c.iter().zip(want).all(|(x, y)| x.to_bits() == y.to_bits()),
                                "request {t}/{j}: survivor must match the fault-free baseline"
                            );
                            tally.ok += 1;
                        }
                        Ok(WireOutcome::Error(e)) => match e.code {
                            ErrCode::ShedDeadline | ErrCode::ShedCodel | ErrCode::Cancelled => {
                                tally.shed += 1;
                            }
                            ErrCode::Shutdown => tally.refused += 1,
                            _ => tally.errs += 1,
                        },
                        Err(_) => tally.lost += 1,
                    }
                    // ordering: relaxed — progress counter for the test driver
                    outcomes.fetch_add(1, Ordering::Relaxed);
                }
                tally
            })
        })
        .collect();

    // mid-traffic shutdown: drain once half the requests have resolved,
    // while the other half are still in flight or still being submitted
    let half = (N_CLIENTS * PER_CLIENT / 2) as u64;
    // ordering: relaxed — progress polling, no synchronization carried
    while outcomes.load(Ordering::Relaxed) < half {
        std::thread::sleep(Duration::from_millis(5));
    }
    let snap = net.shutdown();

    let mut total = Tally::default();
    for h in clients {
        total.add(h.join().expect("client thread must not panic"));
    }

    // exactly one terminal outcome per request, client-side
    assert_eq!(total.total(), (N_CLIENTS * PER_CLIENT) as u64);
    assert!(total.ok >= 1, "some survivors must make it through the chaos");

    // conservation, server-side: every engine submission — including
    // replays that re-executed — lands in exactly one terminal counter
    let terminal =
        snap.completed + snap.errors + snap.shed_deadline + snap.shed_codel + snap.cancelled;
    assert_eq!(terminal, snap.requests, "terminal outcomes must conserve submissions: {snap}");

    // each delivered client outcome is backed by at least one server-side
    // terminal of the same class (replays can only add, never subtract)
    assert!(snap.completed >= total.ok, "{snap}");
    assert!(snap.errors >= total.errs, "{snap}");
    assert!(
        snap.shed_deadline + snap.shed_codel + snap.cancelled >= total.shed,
        "{snap}"
    );

    // the drain actually drained: no wedged connections, wire counters
    // complete in the final snapshot, drain duration recorded
    assert_eq!(snap.conns_open, 0, "shutdown must join every connection: {snap}");
    // at least the setup client plus one chaos client got through the
    // door (threads that lost the race to the shutdown may not have)
    assert!(snap.conns_accepted >= 2, "{snap}");
    assert!(snap.frames_in >= half / 2, "{snap}");
    assert!(snap.frames_out >= total.ok, "{snap}");
    assert!(snap.net_drain_s >= 0.0, "{snap}");
    assert!(
        snap.wire_errors >= 1,
        "torn frames and dropped connections must register as wire errors: {snap}"
    );
}
