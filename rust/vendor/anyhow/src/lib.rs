//! Offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so this
//! vendored shim provides the (small) API subset the crate actually uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros,
//! [`Error::msg`], and the [`Context`] extension trait.  Errors are plain
//! message strings with an optional chain of context lines — no backtraces,
//! no downcasting.  Swap the path dependency for the real crate if a
//! registry ever becomes available; call sites need no changes.

use std::fmt::{self, Debug, Display};

/// A string-backed error type mirroring `anyhow::Error`'s surface.
pub struct Error {
    msg: String,
    /// context lines, outermost first (like anyhow's error chain)
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>`, with the error type defaultable like anyhow's.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
            chain: Vec::new(),
        }
    }

    /// Wrap this error with an outer context line.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in &self.chain {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints the Debug form on exit;
        // keep it readable like anyhow does.
        write!(f, "{self}")
    }
}

// `?` conversion from any std error (mirrors anyhow's blanket impl; sound
// because `Error` itself does not implement `std::error::Error`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

/// Construct an [`Error`] from a message, format string, or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context_chain() {
        let e = Error::msg("root").context("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner: root");
        assert_eq!(format!("{e:?}"), "outer: inner: root");
    }

    #[test]
    fn macro_forms() {
        let lit = anyhow!("plain");
        assert_eq!(lit.to_string(), "plain");
        let owned = anyhow!(String::from("owned"));
        assert_eq!(owned.to_string(), "owned");
        let n = 7;
        let fmt = anyhow!("n = {}", n);
        assert_eq!(fmt.to_string(), "n = 7");
        let inline = anyhow!("n = {n}");
        assert_eq!(inline.to_string(), "n = 7");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("3").unwrap(), 3);
        assert!(parse("x").is_err());
    }

    #[test]
    fn result_context_helpers() {
        let r: std::result::Result<(), String> = Err("boom".into());
        let e = r.clone().context("ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx: boom");
        let e = r.with_context(|| format!("try {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "try 2: boom");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).is_err());
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
