#!/usr/bin/env python3
"""Line-for-line Python mirror of pallas-audit (tools/audit/src/lib.rs).

The container this repo grows in has no Rust toolchain, so the audit pass
is verified by running this mirror over rust/ (the repo convention used by
the BENCH_* placeholders).  Keep the two implementations in lock-step:
every rule change lands in lib.rs AND here, and the fixture expectations
in tools/audit/tests/rules.rs pin both.

usage: python3 tools/audit/pyaudit.py [PATH ...]   (default: rust/)
"""

import os
import sys

RULES = ["R1", "R2", "R3", "R4", "R5", "R6"]
HOT_BANNED = [
    "Instant::now",
    "Vec::new",
    "with_capacity",
    ".to_vec",
    ".collect",
    "Box::new",
    "format!",
]
ATOMIC_ORDERINGS = [
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
]
R5_BEFORE, R5_AFTER = 3, 40
SKIP_DIRS = {"target", "vendor", ".git", "fixtures"}


class Lex:
    def __init__(self):
        self.block_depth = 0
        self.in_str = False
        self.raw_hashes = None


def split_line(st, line):
    b = list(line)
    n = len(b)
    code, comment = [], []
    i = 0
    while i < n:
        if st.block_depth > 0:
            if b[i] == "*" and i + 1 < n and b[i + 1] == "/":
                st.block_depth -= 1
                i += 2
            elif b[i] == "/" and i + 1 < n and b[i + 1] == "*":
                st.block_depth += 1
                i += 2
            else:
                comment.append(b[i])
                i += 1
            continue
        if st.raw_hashes is not None:
            h = st.raw_hashes
            if b[i] == '"' and all(j < n and b[j] == "#" for j in range(i + 1, i + 1 + h)):
                st.raw_hashes = None
                code.append('"')
                i += 1 + h
            else:
                code.append(" ")
                i += 1
            continue
        if st.in_str:
            if b[i] == "\\":
                code.append("  ")
                i += 2
            elif b[i] == '"':
                st.in_str = False
                code.append('"')
                i += 1
            else:
                code.append(" ")
                i += 1
            continue
        c = b[i]
        if c == "/" and i + 1 < n and b[i + 1] == "/":
            comment.extend(b[i + 2:])
            i = n
        elif c == "/" and i + 1 < n and b[i + 1] == "*":
            st.block_depth = 1
            i += 2
        elif c == '"':
            st.in_str = True
            code.append('"')
            i += 1
        elif c == "r" and i + 1 < n and b[i + 1] in ('"', "#"):
            h, j = 0, i + 1
            while j < n and b[j] == "#":
                h += 1
                j += 1
            if j < n and b[j] == '"':
                st.raw_hashes = h
                code.append('"')
                i = j + 1
            else:
                code.append("r")
                i += 1
        elif c == "'":
            if i + 1 < n and b[i + 1] == "\\":
                j = i + 2
                while j < n and b[j] != "'":
                    j += 1
                code.append("' '")
                i = j + 1
            elif i + 2 < n and b[i + 2] == "'":
                code.append("' '")
                i += 3
            else:
                code.append("'")
                i += 1
        else:
            code.append(c)
            i += 1
    return "".join(code), "".join(comment)


def depth_before(codes):
    out, depth = [], 0
    for c in codes:
        out.append(depth)
        depth += c.count("{") - c.count("}")
    return out


def mark_region(mark, depths, start):
    base = depths[start]
    mark[start] = True
    j = start + 1
    while j < len(mark) and depths[j] > base:
        mark[j] = True
        j += 1
    return j


def test_regions(codes, depths, whole_file):
    n = len(codes)
    t = [whole_file] * n
    if whole_file:
        return t
    i = 0
    while i < n:
        if "#[cfg(test)]" in codes[i]:
            t[i] = True
            j = i + 1
            while j < n:
                t[j] = True
                if "{" in codes[j]:
                    i = mark_region(t, depths, j)
                    break
                if codes[j].rstrip().endswith(";"):
                    i = j + 1
                    break
                j += 1
            if j >= n:
                break
        else:
            i += 1
    return t


def hot_regions(comments, codes, depths):
    n = len(codes)
    h = [False] * n
    i = 0
    while i < n:
        if "audit: hot" in comments[i] or "audit:hot" in comments[i]:
            j = i + 1
            while j < n and "{" not in codes[j]:
                j += 1
            if j < n:
                i = mark_region(h, depths, j)
                continue
        i += 1
    return h


def parse_allow(comment):
    # the marker must open the comment: prose that merely mentions the
    # syntax mid-sentence (docs) is not a suppression
    trimmed = comment.lstrip()
    if not trimmed.startswith("audit:allow("):
        return None
    rest = trimmed[len("audit:allow("):]
    close = rest.find(")")
    if close < 0:
        return None
    return rest[:close].strip(), bool(rest[close + 1:].strip())


def unsafe_keyword_rests(code):
    # `unsafe` keyword occurrences only — `unsafe_code` (a lint name) and
    # other identifiers containing the substring are not keywords
    def ident(c):
        return c.isalnum() or c == "_"
    at = code.find("unsafe")
    while at >= 0:
        rest = code[at + len("unsafe"):]
        if (at == 0 or not ident(code[at - 1])) and (not rest or not ident(rest[0])):
            yield rest
        at = code.find("unsafe", at + 1)


def has_safety_comment(codes, comments, i):
    if "SAFETY:" in comments[i]:
        return True
    j = i
    while j > 0:
        j -= 1
        code = codes[j].strip()
        if not code:
            if "SAFETY:" in comments[j]:
                return True
            if not comments[j].strip():
                return False
        elif code.startswith("#[") or code.startswith("#!["):
            continue
        else:
            return False
    return False


def has_ordering_tag(comment):
    lower = comment.lower()
    start = 0
    while True:
        at = lower.find("ordering:", start)
        if at < 0:
            return False
        end = at + len("ordering:")
        if lower[end:end + 1] != ":":
            return True
        start = end


def scan_file(path, src, is_test_file, fault_sites):
    st = Lex()
    raws = src.splitlines()
    pairs = [split_line(st, r) for r in raws]
    codes = [p[0] for p in pairs]
    comments = [p[1] for p in pairs]
    n = len(raws)
    depths = depth_before(codes)
    test = test_regions(codes, depths, is_test_file)
    hot = hot_regions(comments, codes, depths)
    allows = [parse_allow(c) for c in comments]
    out = []

    for i, a in enumerate(allows):
        if a is not None:
            rule, ok = a
            if rule not in RULES:
                out.append((path, i + 1, "R0", f"audit:allow names unknown rule `{rule}`"))
            elif not ok:
                out.append((path, i + 1, "R0",
                            "audit:allow requires a non-empty reason after the rule id"))

    def allowed(i, rule):
        a = allows[i]
        if a is not None and a[0] == rule and a[1]:
            return True
        if i > 0 and not codes[i - 1].strip():
            a = allows[i - 1]
            if a is not None and a[0] == rule and a[1]:
                return True
        return False

    def push(i, rule, msg):
        if not allowed(i, rule):
            out.append((path, i + 1, rule, msg))

    for i in range(n):
        code = codes[i]

        if not test[i] and (".lock().unwrap()" in code or ".lock().expect(" in code):
            push(i, "R1", "poisonable lock acquisition; use util::sync::recover / recover_wait")

        needs = any(
            not rest.lstrip().startswith("fn") for rest in unsafe_keyword_rests(code)
        )
        if needs and not has_safety_comment(codes, comments, i):
            push(i, "R2", "unsafe block without an immediately preceding // SAFETY: comment")

        if hot[i] and not test[i]:
            for tok in HOT_BANNED:
                if tok in code:
                    push(i, "R3", f"`{tok}` inside an `audit: hot` function body")

        if not test[i] and any(o in code for o in ATOMIC_ORDERINGS):
            if "Ordering::SeqCst" in code:
                push(i, "R4", "Ordering::SeqCst is deny-by-default; justify with audit:allow(R4)")
            else:
                here = has_ordering_tag(comments[i])
                above = i > 0 and has_ordering_tag(comments[i - 1])
                if not here and not above:
                    push(i, "R4", "atomic Ordering:: without an `ordering:` rationale "
                                  "on this or the preceding line")

        if not test[i] and "catch_unwind" in code:
            lo = max(0, i - R5_BEFORE)
            hi = min(n - 1, i + R5_AFTER)
            named = any(
                f"FaultSite::{v}" in raws[j] for j in range(lo, hi + 1) for v in fault_sites
            )
            if not named:
                push(i, "R5", "catch_unwind without a FaultSite:: injection point named "
                              "in its window")

    scan_exporters(path, raws, codes, depths, out, allowed)
    return out


def scan_exporters(path, raws, codes, depths, out, allowed):
    n = len(codes)
    fields_at = next((i for i in range(n) if "const FIELDS" in codes[i]), None)
    if fields_at is None:
        return
    fields = []
    for j in range(fields_at, n):
        raw = raws[j].strip()
        if not raw.startswith("//"):
            rest = raws[j]
            while True:
                a = rest.find('"')
                if a < 0:
                    break
                b = rest.find('"', a + 1)
                if b < 0:
                    break
                name = rest[a + 1:b]
                if name and all(c.isalnum() or c == "_" for c in name):
                    fields.append(name)
                rest = rest[b + 1:]
        if "];" in codes[j]:
            break
    if not fields:
        return
    exporters = [
        ("to_json", "fn to_json"),
        ("to_prometheus", "fn to_prometheus"),
        ("Display", "Display for MetricsSnapshot"),
    ]
    for name, anchor in exporters:
        at = next((i for i in range(n) if anchor in codes[i]), None)
        if at is None:
            if not allowed(fields_at, "R6"):
                out.append((path, fields_at + 1, "R6",
                            f"exporter `{name}` not found for MetricsSnapshot::FIELDS"))
            continue
        base = depths[at]
        body = []
        j = at
        while True:
            body.append(codes[j])
            j += 1
            if j >= n or (j > at and depths[j] <= base):
                break
        body = "\n".join(body)
        for f in fields:
            if f"self.{f}" not in body and not allowed(at, "R6"):
                out.append((path, at + 1, "R6",
                            f"FIELDS entry `{f}` is not referenced by exporter `{name}`"))


def parse_fault_sites(src):
    at = src.find("enum FaultSite")
    if at < 0:
        return None
    op = src.find("{", at)
    cl = src.find("}", op)
    if op < 0 or cl < 0:
        return None
    vars_ = []
    for chunk in src[op + 1:cl].split(","):
        v = "".join(l.split("//")[0] for l in chunk.splitlines()).strip()
        if v and v.isalnum():
            vars_.append(v)
    return vars_ or None


def is_test_path(p):
    parts = p.replace("\\", "/").split("/")
    return "tests" in parts or "benches" in parts


def collect_files(root, files):
    if os.path.isfile(root):
        if root.endswith(".rs"):
            files.append(root)
        return
    for entry in sorted(os.listdir(root)):
        p = os.path.join(root, entry)
        if os.path.isdir(p):
            if entry not in SKIP_DIRS:
                collect_files(p, files)
        elif p.endswith(".rs"):
            files.append(p)


def main(argv):
    roots = argv or ["rust"]
    files = []
    for r in roots:
        if not os.path.exists(r):
            print(f"pallas-audit: path does not exist: {r}", file=sys.stderr)
            return 1
        collect_files(r, files)
    fault_sites = ["Exec", "Fused", "Shard", "Pack"]
    for f in files:
        if f.replace("\\", "/").endswith("coordinator/faults.rs"):
            sites = parse_fault_sites(open(f).read())
            if sites:
                fault_sites = sites
    out = []
    for f in files:
        out.extend(scan_file(f, open(f).read(), is_test_path(f), fault_sites))
    out.sort(key=lambda v: (v[0], v[1]))
    if not out:
        print(f"pallas-audit: clean ({len(files)} files)")
        return 0
    for p, line, rule, msg in out:
        print(f"{p}:{line} {rule} {msg}")
    print(f"pallas-audit: {len(out)} violation(s) across {len(files)} files")
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
