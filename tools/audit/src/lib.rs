//! `pallas-audit` — the repo's own static-analysis pass.
//!
//! The serve core leans on invariants no compiler checks: disjoint-row
//! writes through raw `SendPtr` windows, poison-recovering lock
//! discipline, zero-allocation `_into` hot paths, and relaxed-atomic
//! telemetry with argued orderings.  Each of those has regressed (or
//! nearly regressed) in review at least once, so this crate encodes them
//! as scanner rules and CI runs it before the test suite:
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1   | no `.lock().unwrap()` / `.lock().expect(` outside the poison-recovering guard helpers (`util::sync`) |
//! | R2   | every `unsafe` block / `unsafe impl` is immediately preceded by a `// SAFETY:` comment |
//! | R3   | no `Instant::now()` / `Vec::new` / `with_capacity` / `to_vec` / `collect` / `Box::new` / `format!` inside functions stamped `// audit: hot` |
//! | R4   | every atomic `Ordering::` use site carries an `ordering:` rationale comment (same or preceding line); `SeqCst` is deny-by-default |
//! | R5   | every production `catch_unwind` names a matching `FaultSite::` injection point within a ±few-line window |
//! | R6   | every `MetricsSnapshot::FIELDS` entry appears in all three exporters (`to_json`, `to_prometheus`, `Display`) |
//!
//! Suppression is inline and per-site: `// audit:allow(R4) <reason>` on
//! the flagged line, or alone on the line directly above it.  The reason
//! is mandatory — a bare allow is itself a violation.
//!
//! The scanner is a hand-rolled line/token pass, not a parser: the
//! offline vendor convention rules out `syn`/dylint, and these rules are
//! all line-local (plus two brace-matched region kinds: `#[cfg(test)]`
//! items, where R1/R3/R4/R5 relax, and `// audit: hot` function bodies,
//! where R3 arms).  Files under `tests/` or `benches/` directories are
//! wholly test code.  R2 applies everywhere — test unsafe needs a safety
//! argument too.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Rule ids with one-line descriptions (the `--rules` listing).
pub const RULES: &[(&str, &str)] = &[
    ("R1", "lock discipline: use the poison-recovering guards, not .lock().unwrap()"),
    ("R2", "every unsafe block/impl needs an immediately preceding // SAFETY: comment"),
    ("R3", "no allocation/clock tokens inside functions stamped `// audit: hot`"),
    ("R4", "atomic Ordering:: sites need an `ordering:` rationale; SeqCst is deny-by-default"),
    ("R5", "catch_unwind sites must name a FaultSite:: injection point nearby"),
    ("R6", "every MetricsSnapshot::FIELDS entry must appear in all three exporters"),
];

/// Tokens banned inside `// audit: hot` function bodies (R3).
pub const HOT_BANNED: &[&str] = &[
    "Instant::now",
    "Vec::new",
    "with_capacity",
    ".to_vec",
    ".collect",
    "Box::new",
    "format!",
];

/// Atomic memory orderings (R4 matches these, not `cmp::Ordering`).
const ATOMIC_ORDERINGS: &[&str] = &[
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
];

/// How many lines around a `catch_unwind` may carry its `FaultSite::`
/// marker (R5): a few lines above for a comment, the closure body below.
const R5_BEFORE: usize = 3;
const R5_AFTER: usize = 40;

/// One diagnostic, formatted `file:line R# message`.
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: PathBuf,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} {} {}", self.file.display(), self.line, self.rule, self.msg)
    }
}

/// Scanner configuration shared across files.
#[derive(Debug, Clone)]
pub struct Config {
    /// `FaultSite` variants the chaos plan can inject (parsed from
    /// `coordinator/faults.rs` when the walk finds it).
    pub fault_sites: Vec<String>,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            fault_sites: ["Exec", "Fused", "Shard", "Pack"].iter().map(|s| s.to_string()).collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Lexing: split each line into (code, comment), carrying string/comment
// state across lines.  String contents are blanked in `code` so tokens
// inside literals never match a rule.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct LexState {
    /// `/* */` nesting depth (Rust block comments nest)
    block_depth: usize,
    /// inside a normal `"…"` string (may span lines)
    in_str: bool,
    /// inside a raw string, with its `#` count
    raw_hashes: Option<usize>,
}

struct Line {
    /// code text with string contents blanked to spaces
    code: String,
    /// comment text (line + block comments on this line)
    comment: String,
    /// the raw source line
    raw: String,
}

fn split_line(st: &mut LexState, line: &str) -> (String, String) {
    let b: Vec<char> = line.chars().collect();
    let n = b.len();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0usize;
    while i < n {
        if st.block_depth > 0 {
            if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                st.block_depth -= 1;
                i += 2;
            } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                st.block_depth += 1;
                i += 2;
            } else {
                comment.push(b[i]);
                i += 1;
            }
            continue;
        }
        if let Some(h) = st.raw_hashes {
            if b[i] == '"' && (i + 1..=i + h).all(|j| j < n && b[j] == '#') {
                st.raw_hashes = None;
                code.push('"');
                i += 1 + h;
            } else {
                code.push(' ');
                i += 1;
            }
            continue;
        }
        if st.in_str {
            if b[i] == '\\' {
                code.push(' ');
                if i + 1 < n {
                    code.push(' ');
                }
                i += 2;
            } else if b[i] == '"' {
                st.in_str = false;
                code.push('"');
                i += 1;
            } else {
                code.push(' ');
                i += 1;
            }
            continue;
        }
        match b[i] {
            '/' if i + 1 < n && b[i + 1] == '/' => {
                comment.extend(&b[i + 2..]);
                i = n;
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                st.block_depth = 1;
                i += 2;
            }
            '"' => {
                st.in_str = true;
                code.push('"');
                i += 1;
            }
            'r' if i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '#') => {
                // raw string r"…" / r#"…"# — but not raw idents (r#ident)
                let mut h = 0usize;
                let mut j = i + 1;
                while j < n && b[j] == '#' {
                    h += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    st.raw_hashes = Some(h);
                    code.push('"');
                    i = j + 1;
                } else {
                    code.push('r');
                    i += 1;
                }
            }
            '\'' => {
                // char literal vs lifetime: 'x' has a closing quote two
                // chars on; '\…' is always a char escape
                if i + 1 < n && b[i + 1] == '\\' {
                    let mut j = i + 2;
                    while j < n && b[j] != '\'' {
                        j += 1;
                    }
                    code.push_str("' '");
                    i = j + 1;
                } else if i + 2 < n && b[i + 2] == '\'' {
                    code.push_str("' '");
                    i += 3;
                } else {
                    code.push('\'');
                    i += 1;
                }
            }
            c => {
                code.push(c);
                i += 1;
            }
        }
    }
    (code, comment)
}

// ---------------------------------------------------------------------------
// Region detection
// ---------------------------------------------------------------------------

/// Brace depth at the start of each line (from blanked code text).
fn depth_before(lines: &[Line]) -> Vec<i32> {
    let mut out = Vec::with_capacity(lines.len());
    let mut depth = 0i32;
    for l in lines {
        out.push(depth);
        for c in l.code.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
    }
    out
}

/// Mark the brace-matched region of the item that starts at (or follows)
/// line `i`: every line until depth returns to the item's base depth.
/// Returns the first line index past the region.
fn mark_region(mark: &mut [bool], depths: &[i32], start: usize) -> usize {
    let base = depths[start];
    mark[start] = true;
    let mut j = start + 1;
    while j < mark.len() && depths[j] > base {
        mark[j] = true;
        j += 1;
    }
    j
}

/// Lines inside `#[cfg(test)]` items (R1/R3/R4/R5 relax there).
fn test_regions(lines: &[Line], depths: &[i32], whole_file: bool) -> Vec<bool> {
    let n = lines.len();
    let mut t = vec![whole_file; n];
    if whole_file {
        return t;
    }
    let mut i = 0usize;
    while i < n {
        if lines[i].code.contains("#[cfg(test)]") {
            t[i] = true;
            // skip further attributes / signature lines to the item's `{`
            // (a brace-less item — a const, a use — ends at its `;`)
            let mut j = i + 1;
            while j < n {
                t[j] = true;
                if lines[j].code.contains('{') {
                    i = mark_region(&mut t, depths, j);
                    break;
                }
                if lines[j].code.trim_end().ends_with(';') {
                    i = j + 1;
                    break;
                }
                j += 1;
            }
            if j >= n {
                break;
            }
        } else {
            i += 1;
        }
    }
    t
}

/// Function bodies stamped `// audit: hot` (R3 arms inside them).
fn hot_regions(lines: &[Line], depths: &[i32]) -> Vec<bool> {
    let n = lines.len();
    let mut h = vec![false; n];
    let mut i = 0usize;
    while i < n {
        if lines[i].comment.contains("audit: hot") || lines[i].comment.contains("audit:hot") {
            // find the stamped fn's opening brace (attributes and a
            // multi-line signature may sit in between)
            let mut j = i + 1;
            while j < n && !lines[j].code.contains('{') {
                j += 1;
            }
            if j < n {
                i = mark_region(&mut h, depths, j);
                continue;
            }
        }
        i += 1;
    }
    h
}

// ---------------------------------------------------------------------------
// Inline allow-list
// ---------------------------------------------------------------------------

struct Allow {
    rule: String,
    reason_ok: bool,
}

/// Parse `audit:allow(<rule>) <reason>` out of a comment.  The marker
/// must open the comment (after whitespace): prose that merely *mentions*
/// the syntax mid-sentence (docs, this file) is not a suppression.
fn parse_allow(comment: &str) -> Option<Allow> {
    let trimmed = comment.trim_start();
    let rest = trimmed.strip_prefix("audit:allow(")?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let reason_ok = !rest[close + 1..].trim().is_empty();
    Some(Allow { rule, reason_ok })
}

/// The `unsafe` *keyword* occurrences in a code line — an identifier that
/// merely contains the substring (the `unsafe_code` lint name, a
/// `not_unsafe` symbol) is not a keyword.  Yields the rest of the line
/// after each keyword.
fn unsafe_keyword_rests(code: &str) -> impl Iterator<Item = &str> {
    code.match_indices("unsafe").filter_map(|(at, _)| {
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let rest = &code[at + "unsafe".len()..];
        let after_ok = !rest.chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_');
        (before_ok && after_ok).then_some(rest)
    })
}

// ---------------------------------------------------------------------------
// The per-file scan
// ---------------------------------------------------------------------------

/// Scan one file's source.  `is_test_file` marks whole-file test code
/// (anything under a `tests/` or `benches/` directory).
pub fn scan_file(path: &Path, src: &str, is_test_file: bool, cfg: &Config) -> Vec<Violation> {
    let mut st = LexState::default();
    let lines: Vec<Line> = src
        .lines()
        .map(|raw| {
            let (code, comment) = split_line(&mut st, raw);
            Line { code, comment, raw: raw.to_string() }
        })
        .collect();
    let n = lines.len();
    let depths = depth_before(&lines);
    let test = test_regions(&lines, &depths, is_test_file);
    let hot = hot_regions(&lines, &depths);

    let allows: Vec<Option<Allow>> = lines.iter().map(|l| parse_allow(&l.comment)).collect();
    let mut out: Vec<Violation> = Vec::new();

    // malformed allows are themselves violations (unknown rule, no reason)
    for (i, a) in allows.iter().enumerate() {
        if let Some(a) = a {
            if !RULES.iter().any(|(r, _)| *r == a.rule) {
                out.push(Violation {
                    file: path.to_path_buf(),
                    line: i + 1,
                    rule: "R0",
                    msg: format!("audit:allow names unknown rule `{}`", a.rule),
                });
            } else if !a.reason_ok {
                out.push(Violation {
                    file: path.to_path_buf(),
                    line: i + 1,
                    rule: "R0",
                    msg: "audit:allow requires a non-empty reason after the rule id".into(),
                });
            }
        }
    }

    // a violation at line i is suppressed by an allow for its rule on the
    // same line, or alone on the comment-only line directly above
    let allowed = |i: usize, rule: &str| -> bool {
        if let Some(a) = &allows[i] {
            if a.rule == rule && a.reason_ok {
                return true;
            }
        }
        if i > 0 && lines[i - 1].code.trim().is_empty() {
            if let Some(a) = &allows[i - 1] {
                if a.rule == rule && a.reason_ok {
                    return true;
                }
            }
        }
        false
    };
    let push = |i: usize, rule: &'static str, msg: String, out: &mut Vec<Violation>| {
        if !allowed(i, rule) {
            out.push(Violation { file: path.to_path_buf(), line: i + 1, rule, msg });
        }
    };

    for i in 0..n {
        let code = &lines[i].code;

        // R1 — lock discipline (production code only; the guard helpers
        // use unwrap_or_else(PoisonError::into_inner), which never matches)
        if !test[i] && (code.contains(".lock().unwrap()") || code.contains(".lock().expect(")) {
            push(
                i,
                "R1",
                "poisonable lock acquisition; use util::sync::recover / recover_wait".into(),
                &mut out,
            );
        }

        // R2 — SAFETY comments on unsafe blocks and unsafe impls
        // (`unsafe fn` declarations and fn-pointer types are not blocks)
        {
            let needs = unsafe_keyword_rests(code)
                .any(|rest| !rest.trim_start().starts_with("fn"));
            if needs && !has_safety_comment(&lines, i) {
                push(
                    i,
                    "R2",
                    "unsafe block without an immediately preceding // SAFETY: comment".into(),
                    &mut out,
                );
            }
        }

        // R3 — allocation/clock bans inside `// audit: hot` bodies
        if hot[i] && !test[i] {
            for tok in HOT_BANNED {
                if code.contains(tok) {
                    push(
                        i,
                        "R3",
                        format!("`{tok}` inside an `audit: hot` function body"),
                        &mut out,
                    );
                }
            }
        }

        // R4 — atomic ordering rationales
        if !test[i] && ATOMIC_ORDERINGS.iter().any(|o| code.contains(o)) {
            if code.contains("Ordering::SeqCst") {
                push(
                    i,
                    "R4",
                    "Ordering::SeqCst is deny-by-default; justify with audit:allow(R4)".into(),
                    &mut out,
                );
            } else {
                let here = has_ordering_tag(&lines[i].comment);
                let above = i > 0 && has_ordering_tag(&lines[i - 1].comment);
                if !here && !above {
                    push(
                        i,
                        "R4",
                        "atomic Ordering:: without an `ordering:` rationale on this or the preceding line"
                            .into(),
                        &mut out,
                    );
                }
            }
        }

        // R5 — chaos coverage of panic boundaries
        if !test[i] && code.contains("catch_unwind") {
            let lo = i.saturating_sub(R5_BEFORE);
            let hi = (i + R5_AFTER).min(n.saturating_sub(1));
            let named = (lo..=hi).any(|j| {
                cfg.fault_sites
                    .iter()
                    .any(|v| lines[j].raw.contains(&format!("FaultSite::{v}")))
            });
            if !named {
                push(
                    i,
                    "R5",
                    "catch_unwind without a FaultSite:: injection point named in its window".into(),
                    &mut out,
                );
            }
        }
    }

    scan_exporters(path, &lines, &depths, &mut out, &allowed);
    out
}

/// R2 helper: `// SAFETY:` on the same line, or in the contiguous
/// comment/attribute block directly above.
fn has_safety_comment(lines: &[Line], i: usize) -> bool {
    if lines[i].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        if code.is_empty() {
            if lines[j].comment.contains("SAFETY:") {
                return true;
            }
            if lines[j].comment.trim().is_empty() {
                return false; // blank line breaks the block
            }
        } else if code.starts_with("#[") || code.starts_with("#![") {
            continue; // attributes are transparent
        } else {
            return false;
        }
    }
    false
}

/// R4 helper: an `ordering:` tag (the rationale convention), but not the
/// `Ordering::` type path itself appearing inside a comment.
fn has_ordering_tag(comment: &str) -> bool {
    let lower = comment.to_lowercase();
    let mut from = 0usize;
    while let Some(at) = lower[from..].find("ordering:") {
        let end = from + at + "ordering:".len();
        if lower[end..].chars().next() != Some(':') {
            return true;
        }
        from = end;
    }
    false
}

/// R6 — cross-check `MetricsSnapshot::FIELDS` against the three exporters.
fn scan_exporters(
    path: &Path,
    lines: &[Line],
    depths: &[i32],
    out: &mut Vec<Violation>,
    allowed: &dyn Fn(usize, &str) -> bool,
) {
    let n = lines.len();
    let Some(fields_at) = (0..n).find(|&i| lines[i].code.contains("const FIELDS")) else {
        return;
    };
    // collect the entry names (string literals up to the closing `];`)
    let mut fields: Vec<(String, usize)> = Vec::new();
    for (j, l) in lines.iter().enumerate().skip(fields_at) {
        let raw = l.raw.trim();
        if !raw.starts_with("//") {
            let mut rest = l.raw.as_str();
            while let Some(a) = rest.find('"') {
                let Some(b) = rest[a + 1..].find('"') else { break };
                let name = &rest[a + 1..a + 1 + b];
                if !name.is_empty() && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                    fields.push((name.to_string(), j));
                }
                rest = &rest[a + b + 2..];
            }
        }
        if l.code.contains("];") {
            break;
        }
    }
    if fields.is_empty() {
        return;
    }
    let exporters: [(&str, &[&str]); 3] = [
        ("to_json", &["fn to_json"]),
        ("to_prometheus", &["fn to_prometheus"]),
        ("Display", &["Display for MetricsSnapshot"]),
    ];
    for (name, anchors) in exporters {
        let Some(at) = (0..n).find(|&i| anchors.iter().any(|a| lines[i].code.contains(a))) else {
            if !allowed(fields_at, "R6") {
                out.push(Violation {
                    file: path.to_path_buf(),
                    line: fields_at + 1,
                    rule: "R6",
                    msg: format!("exporter `{name}` not found for MetricsSnapshot::FIELDS"),
                });
            }
            continue;
        };
        // brace-matched body of the exporter
        let base = depths[at];
        let mut body = String::new();
        let mut j = at;
        loop {
            body.push_str(&lines[j].code);
            body.push('\n');
            j += 1;
            if j >= n || (j > at && depths[j] <= base) {
                break;
            }
        }
        for (f, _) in &fields {
            if !body.contains(&format!("self.{f}")) && !allowed(at, "R6") {
                out.push(Violation {
                    file: path.to_path_buf(),
                    line: at + 1,
                    rule: "R6",
                    msg: format!("FIELDS entry `{f}` is not referenced by exporter `{name}`"),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Walking
// ---------------------------------------------------------------------------

/// Directories never scanned: build output, the offline vendor shims, VCS
/// metadata, and the scanner's own violation fixtures.
const SKIP_DIRS: &[&str] = &["target", "vendor", ".git", "fixtures"];

fn collect_files(root: &Path, files: &mut Vec<PathBuf>) {
    if root.is_file() {
        if root.extension().and_then(|e| e.to_str()) == Some("rs") {
            files.push(root.to_path_buf());
        }
        return;
    }
    let Ok(entries) = fs::read_dir(root) else { return };
    let mut names: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    names.sort();
    for p in names {
        if p.is_dir() {
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if !SKIP_DIRS.contains(&name) {
                collect_files(&p, files);
            }
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            files.push(p);
        }
    }
}

fn is_test_path(p: &Path) -> bool {
    p.components().any(|c| {
        matches!(c.as_os_str().to_str(), Some("tests") | Some("benches"))
    })
}

/// Parse `enum FaultSite { … }` variants out of `coordinator/faults.rs`.
fn parse_fault_sites(src: &str) -> Option<Vec<String>> {
    let at = src.find("enum FaultSite")?;
    let open = src[at..].find('{')? + at;
    let close = src[open..].find('}')? + open;
    let vars: Vec<String> = src[open + 1..close]
        .split(',')
        .map(|v| {
            // strip comments and attributes from the variant line(s)
            v.lines()
                .map(|l| l.split("//").next().unwrap_or(""))
                .collect::<String>()
                .trim()
                .to_string()
        })
        .filter(|v| !v.is_empty() && v.chars().all(|c| c.is_ascii_alphanumeric()))
        .collect();
    if vars.is_empty() {
        None
    } else {
        Some(vars)
    }
}

/// Scan every `.rs` file under the given roots.  Returns the violations
/// and the number of files scanned.
pub fn scan_paths(roots: &[PathBuf]) -> (Vec<Violation>, usize) {
    let mut files = Vec::new();
    for r in roots {
        collect_files(r, &mut files);
    }
    files.dedup();
    let mut cfg = Config::default();
    for f in &files {
        if f.ends_with("coordinator/faults.rs") {
            if let Ok(src) = fs::read_to_string(f) {
                if let Some(sites) = parse_fault_sites(&src) {
                    cfg.fault_sites = sites;
                }
            }
        }
    }
    let mut out = Vec::new();
    for f in &files {
        let Ok(src) = fs::read_to_string(f) else { continue };
        out.extend(scan_file(f, &src, is_test_path(f), &cfg));
    }
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    (out, files.len())
}
