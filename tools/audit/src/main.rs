//! CLI for the repo's static-analysis pass.
//!
//! ```text
//! cargo run -p pallas-audit -- rust/
//! ```
//!
//! Exits 0 when every rule holds, 1 with one `file:line R# message`
//! diagnostic per violation otherwise (the CI `audit` step's contract).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("usage: pallas-audit [--rules] [--bench] [PATH ...]   (default PATH: rust/)");
        return ExitCode::SUCCESS;
    }
    if args.iter().any(|a| a == "--rules") {
        for (id, desc) in pallas_audit::RULES {
            println!("{id}  {desc}");
        }
        return ExitCode::SUCCESS;
    }
    let bench = args.iter().any(|a| a == "--bench");
    let roots: Vec<PathBuf> = {
        let paths: Vec<PathBuf> =
            args.iter().filter(|a| !a.starts_with("--")).map(PathBuf::from).collect();
        if paths.is_empty() { vec![PathBuf::from("rust")] } else { paths }
    };
    for r in &roots {
        if !r.exists() {
            eprintln!("pallas-audit: path does not exist: {}", r.display());
            return ExitCode::FAILURE;
        }
    }
    let (violations, files) = pallas_audit::scan_paths(&roots);
    if bench {
        // Time the cold scan above plus repeated warm scans, then refresh
        // the committed snapshot (same pending-toolchain convention as the
        // other BENCH_*.json writers).
        const REPS: usize = 5;
        let mut times_ms = Vec::with_capacity(REPS);
        for _ in 0..REPS {
            let start = std::time::Instant::now();
            let _ = pallas_audit::scan_paths(&roots);
            times_ms.push(start.elapsed().as_secs_f64() * 1e3);
        }
        times_ms.sort_by(|a, b| a.partial_cmp(b).expect("elapsed times are finite"));
        let json = format!(
            "{{\n  \"format\": \"bench-audit-v1\",\n  \"status\": \"measured\",\n  \
             \"command\": \"cargo run --release -p pallas-audit -- --bench rust/\",\n  \
             \"files_scanned\": {files},\n  \"reps\": {REPS},\n  \
             \"scan_ms_median\": {:.3},\n  \"violations\": {},\n  \
             \"rules\": [\"R1\", \"R2\", \"R3\", \"R4\", \"R5\", \"R6\"]\n}}\n",
            times_ms[REPS / 2],
            violations.len(),
        );
        if let Err(e) = std::fs::write("BENCH_audit.json", json) {
            eprintln!("pallas-audit: could not write BENCH_audit.json: {e}");
        }
    }
    if violations.is_empty() {
        println!("pallas-audit: clean ({files} files)");
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            println!("{v}");
        }
        println!("pallas-audit: {} violation(s) across {files} files", violations.len());
        ExitCode::FAILURE
    }
}
