// R1 fixture: one bare lock().unwrap() violation, one suppressed site,
// and one guard-helper use that must NOT match.
use std::sync::Mutex;

fn violating(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap() // line 6: R1 violation
}

fn suppressed(m: &Mutex<u32>) -> u32 {
    // audit:allow(R1) fixture: exercising the suppression path
    *m.lock().unwrap()
}

fn guard(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
