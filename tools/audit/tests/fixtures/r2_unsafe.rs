// R2 fixture: one undocumented unsafe block, one suppressed, one
// documented (must NOT flag), and an `unsafe fn` declaration (exempt).

fn violating(p: *const u8) -> u8 {
    unsafe { *p } // line 5: R2 violation
}

fn suppressed(p: *const u8) -> u8 {
    // audit:allow(R2) fixture: exercising the suppression path
    unsafe { *p }
}

fn documented(p: *const u8) -> u8 {
    // SAFETY: fixture pointer is always valid
    unsafe { *p }
}

unsafe fn declaration_is_exempt(p: *const u8) -> u8 {
    *p
}
