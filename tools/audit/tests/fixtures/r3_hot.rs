// R3 fixture: an allocation inside a hot-stamped body, a suppressed one,
// and the same token in a cold function (must NOT flag).

// audit: hot — fixture kernel
fn hot_violating(n: usize) -> Vec<u32> {
    let out = Vec::with_capacity(n); // line 6: R3 violation
    out
}

// audit: hot — fixture kernel with a justified allocation
fn hot_suppressed(n: usize) -> Vec<u32> {
    // audit:allow(R3) fixture: exercising the suppression path
    let out = Vec::with_capacity(n);
    out
}

fn cold_is_exempt(n: usize) -> Vec<u32> {
    Vec::with_capacity(n)
}
