// R4 fixture: an unannotated atomic ordering, a SeqCst (deny-by-default),
// a suppressed SeqCst, and two annotated sites (must NOT flag).
use std::sync::atomic::{AtomicU64, Ordering};

fn violating(a: &AtomicU64) -> u64 {
    a.load(Ordering::Relaxed) // line 6: R4 violation (no rationale)
}

fn seqcst_denied(a: &AtomicU64) {
    a.store(1, Ordering::SeqCst); // line 10: R4 violation (SeqCst)
}

fn seqcst_suppressed(a: &AtomicU64) {
    // audit:allow(R4) fixture: exercising the SeqCst suppression path
    a.store(1, Ordering::SeqCst);
}

fn annotated_trailing(a: &AtomicU64) -> u64 {
    a.load(Ordering::Relaxed) // ordering: relaxed — fixture counter
}

fn annotated_preceding(a: &AtomicU64) -> u64 {
    // ordering: relaxed — fixture counter
    a.load(Ordering::Relaxed)
}
