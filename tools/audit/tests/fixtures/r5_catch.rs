// R5 fixture: a catch_unwind with no FaultSite in its window, a
// suppressed one, and one that names its injection site (must NOT flag).
// The named site sits more than R5_AFTER lines below the violating catch
// so their windows cannot overlap.

fn violating() {
    let _ = std::panic::catch_unwind(|| {}); // line 7: R5 violation
}

fn suppressed() {
    // audit:allow(R5) fixture: exercising the suppression path
    let _ = std::panic::catch_unwind(|| {});
}

// -- window padding ---------------------------------------------------------
// pad 01
// pad 02
// pad 03
// pad 04
// pad 05
// pad 06
// pad 07
// pad 08
// pad 09
// pad 10
// pad 11
// pad 12
// pad 13
// pad 14
// pad 15
// pad 16
// pad 17
// pad 18
// pad 19
// pad 20
// pad 21
// pad 22
// pad 23
// pad 24
// pad 25
// pad 26
// pad 27
// pad 28
// pad 29
// pad 30
// pad 31
// pad 32
// pad 33
// pad 34
// pad 35
// ---------------------------------------------------------------------------

fn named() {
    // exercised by fault injection at FaultSite::Exec
    let _ = std::panic::catch_unwind(|| {});
}
