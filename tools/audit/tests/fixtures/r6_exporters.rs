// R6 fixture: a FIELDS inventory whose `missing` entry is exported by
// to_json and to_prometheus but NOT by Display → exactly one violation,
// anchored at the Display line.

pub struct MetricsSnapshot {
    pub covered: u64,
    pub missing: u64,
}

impl MetricsSnapshot {
    pub const FIELDS: &'static [&'static str] = &[
        "covered", // line 12
        "missing", // line 13
    ];

    pub fn to_json(&self) -> String {
        format!("{{\"covered\":{},\"missing\":{}}}", self.covered, self.missing)
    }

    pub fn to_prometheus(&self) -> String {
        format!("covered {}\nmissing {}\n", self.covered, self.missing)
    }
}

impl std::fmt::Display for MetricsSnapshot {
    // line 25 anchors the violation: `missing` never printed here
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "covered={}", self.covered)
    }
}
