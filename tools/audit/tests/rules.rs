//! Fixture tests: one file per rule under `tests/fixtures/`, each holding
//! exactly one intended violation at a pinned line, one `audit:allow`
//! suppression, and the rule's negative cases. The fixtures directory is in
//! the walker's skip list, so these tests feed `scan_file` directly.
//!
//! Integration tests run with the package directory as cwd, so fixture
//! paths are relative to `tools/audit/`.

use std::path::Path;

use pallas_audit::{scan_file, Config, RULES};

/// Scan a fixture as if it were production code (`is_test_file = false`)
/// and return its `(rule, line)` pairs in file order.
fn scan_fixture(name: &str) -> Vec<(&'static str, usize)> {
    let path = Path::new("tests/fixtures").join(name);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", path.display()));
    scan_file(&path, &src, false, &Config::default())
        .into_iter()
        .map(|v| (v.rule, v.line))
        .collect()
}

#[test]
fn r1_flags_bare_lock_unwrap_once() {
    // Line 6 is the bare `.lock().unwrap()`; line 11 is suppressed and the
    // `unwrap_or_else(PoisonError::into_inner)` guard at 15 must not match.
    assert_eq!(scan_fixture("r1_lock.rs"), vec![("R1", 6)]);
}

#[test]
fn r2_flags_undocumented_unsafe_once() {
    // Line 5 lacks a SAFETY comment; the suppressed (10), documented (15),
    // and `unsafe fn` declaration (18) sites are exempt.
    assert_eq!(scan_fixture("r2_unsafe.rs"), vec![("R2", 5)]);
}

#[test]
fn r3_flags_hot_allocation_once() {
    // Line 6 allocates inside an `audit: hot` body; the suppressed hot site
    // (13) and the cold function (18) are exempt.
    assert_eq!(scan_fixture("r3_hot.rs"), vec![("R3", 6)]);
}

#[test]
fn r4_flags_unannotated_and_seqcst() {
    // Line 6 has no `ordering:` rationale; line 10 is SeqCst
    // (deny-by-default). Suppressed SeqCst (15) and both annotated sites
    // (19, 24) are exempt.
    assert_eq!(scan_fixture("r4_ordering.rs"), vec![("R4", 6), ("R4", 10)]);
}

#[test]
fn r5_flags_unnamed_catch_unwind_once() {
    // Line 7's window names no FaultSite; the suppressed site (12) and the
    // named site (55, with `FaultSite::Exec` in-window) are exempt.
    assert_eq!(scan_fixture("r5_catch.rs"), vec![("R5", 7)]);
}

#[test]
fn r6_flags_missing_exporter_field_once() {
    // `missing` is exported by to_json and to_prometheus but not Display;
    // the violation anchors at the Display impl line.
    assert_eq!(scan_fixture("r6_exporters.rs"), vec![("R6", 25)]);
}

#[test]
fn r6_names_the_field_and_exporter() {
    let path = Path::new("tests/fixtures/r6_exporters.rs");
    let src = std::fs::read_to_string(path).unwrap();
    let vs = scan_file(path, &src, false, &Config::default());
    assert_eq!(vs.len(), 1);
    assert!(vs[0].msg.contains("`missing`"), "msg: {}", vs[0].msg);
    assert!(vs[0].msg.contains("`Display`"), "msg: {}", vs[0].msg);
}

#[test]
fn test_files_relax_lock_and_ordering_rules() {
    // The same fixtures scanned as test code keep only the rules that still
    // apply there (R2 documents unsafe everywhere; R6 is structural).
    assert_eq!(scan_fixture_as_test("r1_lock.rs"), vec![]);
    assert_eq!(scan_fixture_as_test("r4_ordering.rs"), vec![]);
    assert_eq!(scan_fixture_as_test("r2_unsafe.rs"), vec![("R2", 5)]);
}

fn scan_fixture_as_test(name: &str) -> Vec<(&'static str, usize)> {
    let path = Path::new("tests/fixtures").join(name);
    let src = std::fs::read_to_string(&path).unwrap();
    scan_file(&path, &src, true, &Config::default())
        .into_iter()
        .map(|v| (v.rule, v.line))
        .collect()
}

#[test]
fn allow_with_unknown_rule_is_r0() {
    let src = "// audit:allow(R9) no such rule\nfn f() {}\n";
    let vs = scan_file(Path::new("inline.rs"), src, false, &Config::default());
    assert_eq!(vs.len(), 1);
    assert_eq!((vs[0].rule, vs[0].line), ("R0", 1));
    assert!(vs[0].msg.contains("unknown rule"), "msg: {}", vs[0].msg);
}

#[test]
fn allow_without_reason_is_r0() {
    let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    // audit:allow(R1)\n    *m.lock().unwrap()\n}\n";
    let vs = scan_file(Path::new("inline.rs"), src, false, &Config::default());
    // The empty reason is R0 *and* fails to suppress the R1 underneath.
    let pairs: Vec<_> = vs.iter().map(|v| (v.rule, v.line)).collect();
    assert_eq!(pairs, vec![("R0", 2), ("R1", 3)]);
}

#[test]
fn every_fixture_rule_is_registered() {
    for rule in ["R1", "R2", "R3", "R4", "R5", "R6"] {
        assert!(
            RULES.iter().any(|(id, _)| *id == rule),
            "rule {rule} missing from RULES"
        );
    }
}
